"""Elastic device-fleet sweeps: health registry, stragglers, SDC.

Covers the PR-10 acceptance matrix:
  * ``DevicePool`` admission prefers idle healthy devices, quarantines
    via per-device circuit breakers, and re-admits through the
    half-open probe;
  * fleet-layer fault injection (``slow`` / ``corrupt`` /
    ``device-lost``) is deterministic and device/chunk-targeted;
  * chaos property: a fault injected at *every* chunk boundary — one
    kind at a time and all three together — leaves the final fronts
    bit-identical to a solo single-device run, with the mitigation
    counters (``n_speculative`` / ``n_resharded`` /
    ``n_corruption_checks``) surfaced in ``StreamResult.meta``;
  * the SDC sentinel detects a silently-corrupting device by numpy-rung
    recomputation (parity is exact, so any mismatch is corruption),
    quarantines it, and replays its chunks;
  * watchdog threads are tracked, reaped, and reported as
    ``n_leaked_watchdogs`` (0 on every healthy path);
  * on a real 8-device jax host the fleet path reproduces the solo
    numpy front bit for bit (subprocess, ``slow`` marker).
"""
import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.explore import (ChunkTask, DevicePool, Fault, FaultPlan,
                           ParetoAccumulator, ResiliencePolicy, RetryPolicy,
                           Rung, StatsAccumulator, SweepJournal,
                           TopKAccumulator, run_fleet)
from repro.explore.fleet import (_Shard, device_topology, pin,
                                 pinned_device, visible_devices)
from repro.explore.frame import ResultFrame
from repro.explore.resilience import ANY_CHUNK, WatchdogRegistry
from repro.explore.streaming import run_stream

METRICS = ("latency_s", "power_mw", "area_mm2")
ROWS = 6


def no_wait() -> RetryPolicy:
  return RetryPolicy(sleep=lambda s: None)


def chunk_result(i: int, n: int = ROWS):
  """Pure function of the chunk index — the fleet bit-identity premise."""
  rng = np.random.RandomState(1000 + i)
  frame = ResultFrame(rng.rand(n), rng.rand(n), rng.rand(n),
                      ["pe"] * n, (), "net")
  return frame, np.arange(i * n, (i + 1) * n, dtype=np.int64)


def fleet_tasks(n_chunks: int):
  """ChunkTasks whose 'device' rung is numpy under the hood, so the
  terminal-rung parity the SDC sentinel relies on is exact by
  construction (as it is for the real x64 device path)."""
  return [ChunkTask(i, (Rung("device", lambda i=i: chunk_result(i),
                             layer="device"),
                        Rung("numpy", lambda i=i: chunk_result(i))))
          for i in range(n_chunks)]


def make_pool(n_devices: int = 4, **kw) -> DevicePool:
  kw.setdefault("speculation_factor", 4.0)
  return DevicePool(devices=[f"fake{i}" for i in range(n_devices)], **kw)


def reducer_set():
  return {"pareto": ParetoAccumulator(),
          "top": TopKAccumulator(k=5, by="latency_s"),
          "stats": StatsAccumulator("latency_s")}


def solo_result(n_chunks: int):
  return run_stream(fleet_tasks(n_chunks), reducer_set())


def assert_fronts_identical(res, ref):
  for name in ("pareto", "top"):
    a, b = res.results[name], ref.results[name]
    for col in METRICS:
      assert np.array_equal(getattr(a, col), getattr(b, col)), (name, col)
  # Pareto/TopK are exactly chunk-order-invariant; Stats count/min/max
  # are too, but mean/std are only associativity-level under the reorder
  # a requeue introduces (documented on StatsAccumulator).
  s, r = res.results["stats"], ref.results["stats"]
  for key in ("count", "min", "max"):
    assert s[key] == r[key], key
  for key in ("mean", "std"):
    assert s[key] == pytest.approx(r[key], rel=1e-12), key
  assert res.n_rows == ref.n_rows


# ---------------------------------------------------------------------------
# pinning
# ---------------------------------------------------------------------------

class TestPin:

  def test_pin_nests_and_restores(self):
    assert pinned_device() is None
    with pin("d0"):
      assert pinned_device() == "d0"
      with pin("d1"):
        assert pinned_device() == "d1"
      assert pinned_device() == "d0"
    assert pinned_device() is None

  def test_pin_is_thread_local(self):
    seen = []
    with pin("main-dev"):
      t = threading.Thread(target=lambda: seen.append(pinned_device()))
      t.start()
      t.join(5.0)
    assert seen == [None]


# ---------------------------------------------------------------------------
# the health registry
# ---------------------------------------------------------------------------

class TestDevicePool:

  def test_validation(self):
    with pytest.raises(ValueError):
      DevicePool(devices=[])
    with pytest.raises(ValueError):
      make_pool(speculation_factor=1.0)
    with pytest.raises(ValueError):
      make_pool(sdc_check_every=-1)

  def test_checkout_balances_outstanding(self):
    pool = make_pool(3)
    picks = [pool.checkout() for _ in range(6)]
    assert sorted(picks[:3]) == [0, 1, 2]   # one each before any repeats
    assert sorted(picks[3:]) == [0, 1, 2]
    for i in picks:
      pool.checkin(i)

  def test_require_idle_excludes_busy_devices(self):
    pool = make_pool(2)
    a = pool.checkout()
    alt = pool.checkout(require_idle=True, exclude=(a,))
    assert alt is not None and alt != a
    assert pool.checkout(require_idle=True) is None  # both now busy

  def test_quarantine_skips_device_until_probe(self):
    pool = make_pool(2, breaker_cooldown=3, breaker_jitter=0)
    pool.quarantine(0)
    assert pool.meta()["n_quarantined_devices"] == 1.0
    # each refusal counts down the cooldown; the 3rd consult half-opens
    # and admits device 0 as the probe
    picks = []
    for _ in range(3):
      i = pool.checkout()
      picks.append(i)
      pool.checkin(i)
    assert picks == [1, 1, 0]

  def test_all_quarantined_checkout_returns_none(self):
    pool = make_pool(2, breaker_cooldown=50, breaker_jitter=0)
    pool.quarantine(0)
    pool.quarantine(1)
    assert pool.checkout() is None

  def test_lost_device_rejoins_via_half_open_probe(self):
    pool = make_pool(2, breaker_cooldown=2, breaker_jitter=0)
    pool.lose_device(0)
    assert pool.counters()["n_device_losses"] == 1
    # drain the cooldown with checkouts; device 0 must eventually probe
    seen = set()
    for _ in range(8):
      i = pool.checkout()
      if i is None:
        continue
      seen.add(i)
      pool.record_success(i)
      pool.checkin(i)
    assert 0 in seen

  def test_latency_feed_and_fleet_median(self):
    pool = make_pool(2, ewma_alpha=0.5)
    assert pool.fleet_latency() is None
    for _ in range(4):
      pool.record_latency(0, 1.0)
      pool.record_latency(1, 3.0)
    assert pool.ewma(0) == pytest.approx(1.0)
    med = pool.fleet_latency()
    assert med is not None and 1.0 <= med <= 3.0

  def test_meta_shape(self):
    pool = make_pool(3)
    meta = pool.meta()
    assert meta["fleet_devices"] == 3.0
    assert len(meta["fleet_device_states"]) == 3
    assert len(meta["fleet_device_ewma_s"]) == 3
    for key in ("n_speculative", "n_resharded", "n_corruption_checks",
                "n_corruptions_detected", "n_device_losses"):
      assert meta[key] == 0.0


# ---------------------------------------------------------------------------
# fleet fault injection
# ---------------------------------------------------------------------------

class TestFleetFaults:

  def test_kind_layer_validation(self):
    with pytest.raises(ValueError):
      Fault("slow", 0, "device")          # fleet kinds need layer=fleet
    with pytest.raises(ValueError):
      Fault("raise", 0, "fleet")          # and only fleet kinds may use it
    with pytest.raises(ValueError):
      Fault("raise", 0, "device", device=1)   # device targeting fleet-only
    with pytest.raises(ValueError):
      Fault("raise", ANY_CHUNK, "device")     # wildcard fleet-only

  def test_check_fleet_targets_device_and_chunk(self):
    plan = FaultPlan([Fault("slow", 3, "fleet", device=1)])
    assert plan.check_fleet(0, 3) is None
    assert plan.check_fleet(1, 2) is None
    assert plan.check_fleet(1, 3) == "slow"
    assert plan.check_fleet(1, 3) is None   # times budget spent
    assert plan.n_fired == 1

  def test_any_chunk_wildcard_models_sick_device(self):
    plan = FaultPlan([Fault("corrupt", ANY_CHUNK, "fleet", times=3,
                            device=2)])
    assert [plan.check_fleet(2, c) for c in (7, 11, 13, 17)] == \
        ["corrupt", "corrupt", "corrupt", None]

  def test_seeded_fleet_reproducible(self):
    mk = lambda: FaultPlan.seeded_fleet(9, 40, 4, p_slow=0.3,
                                        p_corrupt=0.2, p_lost=0.1)
    a, b = mk(), mk()
    assert a.faults == b.faults and len(a.faults) > 0
    assert all(f.layer == "fleet" for f in a.faults)
    assert FaultPlan.seeded_fleet(10, 40, 4, p_slow=0.3).faults != a.faults


# ---------------------------------------------------------------------------
# fleet execution: healthy path
# ---------------------------------------------------------------------------

class TestFleetHealthy:

  def test_fronts_match_solo_run(self):
    ref = solo_result(10)
    res = run_stream(fleet_tasks(10), reducer_set(), pool=make_pool(4))
    assert_fronts_identical(res, ref)

  def test_meta_carries_fleet_counters(self):
    res = run_stream(fleet_tasks(6), reducer_set(), pool=make_pool(2),
                     policy=ResiliencePolicy(retry=no_wait()))
    for key in ("n_speculative", "n_resharded", "n_corruption_checks",
                "fleet_devices", "fleet_device_states",
                "n_quarantined_devices"):
      assert key in res.meta
    assert res.meta["n_leaked_watchdogs"] == 0.0
    assert res.meta["fleet_devices"] == 2.0
    assert res.meta["n_chunks"] == 6.0

  def test_sdc_sentinel_zero_and_nonzero_overhead_paths(self):
    ref = solo_result(8)
    off = run_stream(fleet_tasks(8), reducer_set(),
                     pool=make_pool(3, sdc_check_every=0))
    on = run_stream(fleet_tasks(8), reducer_set(),
                    pool=make_pool(3, sdc_check_every=1))
    assert_fronts_identical(off, ref)
    assert_fronts_identical(on, ref)
    assert off.meta["n_corruption_checks"] == 0.0
    assert on.meta["n_corruption_checks"] > 0.0
    assert on.meta["n_corruptions_detected"] == 0.0

  def test_all_devices_quarantined_falls_back_to_terminal_rung(self):
    pool = make_pool(2, breaker_cooldown=100, breaker_jitter=0)
    pool.quarantine(0)
    pool.quarantine(1)
    ref = solo_result(5)
    res = run_stream(fleet_tasks(5), reducer_set(), pool=pool)
    assert_fronts_identical(res, ref)

  def test_resume_from_journal(self, tmp_path):
    ref = solo_result(7)
    jr = SweepJournal(tmp_path)
    key = "f" * 64
    half = run_fleet(fleet_tasks(7)[:3], reducer_set(), make_pool(2),
                     resume_from=jr, journal_key=key)
    assert half.meta["n_chunks"] == 3.0
    res = run_fleet(fleet_tasks(7), reducer_set(), make_pool(2),
                    resume_from=jr, journal_key=key)
    assert res.meta["n_resumed_chunks"] == 3.0
    assert_fronts_identical(res, ref)


# ---------------------------------------------------------------------------
# chaos: faults at every chunk boundary stay bit-identical
# ---------------------------------------------------------------------------

N_CHAOS_CHUNKS = 8


class TestFleetChaos:

  @pytest.mark.parametrize("kind", ["slow", "corrupt", "device-lost"])
  def test_single_fault_at_every_chunk_boundary(self, kind):
    ref = solo_result(N_CHAOS_CHUNKS)
    for chunk in range(N_CHAOS_CHUNKS):
      plan = FaultPlan([Fault(kind, chunk, "fleet")])
      pool = make_pool(4, sdc_check_every=1)
      res = run_stream(
          fleet_tasks(N_CHAOS_CHUNKS), reducer_set(), pool=pool,
          policy=ResiliencePolicy(retry=no_wait(), fault_plan=plan))
      assert_fronts_identical(res, ref)
      assert res.meta["n_leaked_watchdogs"] == 0.0
      if kind == "device-lost":
        assert plan.n_fired == 1
        assert res.meta["n_device_losses"] == 1.0
        assert res.meta["n_resharded"] >= 1.0
      if kind == "corrupt" and plan.n_fired:
        assert res.meta["n_corruptions_detected"] == 1.0
        assert res.meta["n_corruption_checks"] >= 1.0
        assert res.meta["n_resharded"] >= 1.0

  def test_straggler_speculation_fires_at_the_tail(self):
    # a slow shard near the end of the sweep, when idle devices exist
    ref = solo_result(6)
    plan = FaultPlan([Fault("slow", 5, "fleet")])
    pool = make_pool(3)
    res = run_stream(fleet_tasks(6), reducer_set(), pool=pool,
                     policy=ResiliencePolicy(retry=no_wait(),
                                             fault_plan=plan))
    assert_fronts_identical(res, ref)
    assert res.meta["n_speculative"] >= 1.0

  def test_silently_corrupting_device_quarantined_and_replayed(self):
    # a persistently sick device: every chunk it touches is corrupted
    ref = solo_result(N_CHAOS_CHUNKS)
    plan = FaultPlan([Fault("corrupt", ANY_CHUNK, "fleet", times=100,
                            device=1)])
    pool = make_pool(3, sdc_check_every=1, breaker_cooldown=50,
                     breaker_jitter=0)
    res = run_stream(
        fleet_tasks(N_CHAOS_CHUNKS), reducer_set(), pool=pool,
        policy=ResiliencePolicy(retry=no_wait(), fault_plan=plan))
    assert_fronts_identical(res, ref)
    assert res.meta["n_corruptions_detected"] >= 1.0
    assert "open" in res.meta["fleet_device_states"]

  def test_combined_chaos_run(self):
    # the acceptance scenario: 1 straggler + 1 device lost mid-sweep +
    # 1 corrupting device, all in one sweep
    n = 12
    ref = solo_result(n)
    plan = FaultPlan([Fault("slow", n - 1, "fleet"),
                      Fault("device-lost", 4, "fleet"),
                      Fault("corrupt", 7, "fleet")])
    pool = make_pool(4, sdc_check_every=1)
    res = run_stream(fleet_tasks(n), reducer_set(), pool=pool,
                     policy=ResiliencePolicy(retry=no_wait(),
                                             fault_plan=plan))
    assert_fronts_identical(res, ref)
    assert res.meta["n_device_losses"] == 1.0
    assert res.meta["n_resharded"] >= 1.0
    assert res.meta["n_corruptions_detected"] == 1.0
    assert res.meta["n_leaked_watchdogs"] == 0.0

  def test_seeded_chaos_storm(self):
    # seeded random faults of all three kinds across the whole sweep
    n = 16
    ref = solo_result(n)
    plan = FaultPlan.seeded_fleet(23, n, 4, p_slow=0.25, p_corrupt=0.25,
                                  p_lost=0.15)
    assert len(plan.faults) > 0
    pool = make_pool(4, sdc_check_every=1)
    res = run_stream(fleet_tasks(n), reducer_set(), pool=pool,
                     policy=ResiliencePolicy(retry=no_wait(),
                                             fault_plan=plan))
    assert_fronts_identical(res, ref)
    assert res.meta["n_leaked_watchdogs"] == 0.0


# ---------------------------------------------------------------------------
# watchdog thread accounting (satellite: the daemon-thread leak fix)
# ---------------------------------------------------------------------------

class _FakePending:
  def __init__(self, fn):
    self._fn = fn

  def resolve(self):
    return self._fn()


class TestWatchdogRegistry:

  def test_tracks_and_reaps(self):
    reg = WatchdogRegistry()
    gate = threading.Event()
    t = threading.Thread(target=gate.wait, daemon=True)
    t.start()
    reg.track(t)
    assert reg.n_live() == 1 and reg.n_spawned == 1
    gate.set()
    assert reg.drain(timeout=5.0) == 0
    assert reg.n_reaped == 1

  def test_hung_resolution_is_tracked_not_abandoned(self):
    gate = threading.Event()

    def block():
      gate.wait(30.0)
      return "too-late"

    task = ChunkTask(0, (Rung("device", lambda: _FakePending(block),
                              layer="device"),
                         Rung("numpy", lambda: "rescued")))
    pol = ResiliencePolicy(retry=no_wait(), resolve_timeout=0.05)
    assert pol.execute(task).resolve() == "rescued"
    assert pol.watchdogs.n_live() == 1     # the hung thread is referenced
    gate.set()
    assert pol.watchdogs.drain(timeout=5.0) == 0

  def test_run_stream_reports_zero_leaks_when_healthy(self):
    res = run_stream(fleet_tasks(4), {"pareto": ParetoAccumulator()},
                     policy=ResiliencePolicy(retry=no_wait()))
    assert res.meta["n_leaked_watchdogs"] == 0.0


# ---------------------------------------------------------------------------
# real multi-device bit-identity (subprocess: device count is
# process-start-only)
# ---------------------------------------------------------------------------

_REAL_FLEET_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()
    # the exact-codegen flags must be set before visible_devices()
    # initializes the XLA client, or the parity contract is void
    from repro.explore.device import ensure_exact_cpu_codegen
    ensure_exact_cpu_codegen()
    import numpy as np
    from repro.core.workloads import get_network
    from repro.explore import (DesignSpace, DevicePool, FaultPlan, Fault,
                               ParetoAccumulator, ResiliencePolicy,
                               RetryPolicy, VectorOracleBackend,
                               stream_explore, visible_devices)
    from repro.explore.resilience import ANY_CHUNK

    assert len(visible_devices()) == 8, visible_devices()
    layers = get_network("resnet20")[:4]
    space = DesignSpace()
    mk = lambda: {"pareto": ParetoAccumulator()}
    solo = stream_explore(VectorOracleBackend(), space, layers,
                          n_per_type=120, seed=13, chunk_size=50,
                          reducers=mk(), workers=1)
    pool = DevicePool(sdc_check_every=2)
    plan = FaultPlan([Fault("device-lost", 1, "fleet"),
                      Fault("slow", 3, "fleet"),
                      Fault("corrupt", 2, "fleet")])
    res = stream_explore(
        VectorOracleBackend(jit=True), space, layers, n_per_type=120,
        seed=13, chunk_size=50, reducers=mk(), pool=pool,
        policy=ResiliencePolicy(retry=RetryPolicy(sleep=lambda s: None),
                                fault_plan=plan))
    a, b = res.results["pareto"], solo.results["pareto"]
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(a, col), getattr(b, col)), col
    assert res.n_rows == solo.n_rows
    assert res.meta["fleet_devices"] == 8.0
    assert res.meta["n_device_losses"] == 1.0
    assert res.meta["n_corruption_checks"] >= 1.0
    assert res.meta["n_leaked_watchdogs"] == 0.0
    print("FLEET-8DEV-OK", int(res.meta["n_chunks"]),
          int(res.meta["n_resharded"]))
""")


@pytest.mark.slow
def test_real_eight_device_fleet_bit_identity():
  pytest.importorskip("jax")
  env = dict(os.environ)
  env["PYTHONPATH"] = os.pathsep.join(
      [os.path.join(os.path.dirname(__file__), "..", "src"),
       env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
  env.pop("XLA_FLAGS", None)  # the child builds its own (8 forced devices)
  proc = subprocess.run([sys.executable, "-c", _REAL_FLEET_SCRIPT],
                        capture_output=True, text=True, env=env,
                        timeout=600)
  assert proc.returncode == 0, proc.stderr[-4000:]
  assert "FLEET-8DEV-OK" in proc.stdout
