"""Shared test configuration.

XLA latches its flags at the process's first compilation, and the exact
device path (repro.explore.device) needs FMA contraction and the HLO
algebraic simplifier off to be bit-compatible with numpy.  Other test
modules compile jax programs before the device-sweep tests run, so the
flags must enter the environment before anything compiles — conftest
import is the earliest hook the test process has.
"""
from repro.explore.device import ensure_exact_cpu_codegen

ensure_exact_cpu_codegen()
