"""Shared test configuration.

XLA latches its flags at the process's first compilation, and the exact
device path (repro.explore.device) needs FMA contraction and the HLO
algebraic simplifier off to be bit-compatible with numpy.  Other test
modules compile jax programs before the device-sweep tests run, so the
flags must enter the environment before anything compiles — conftest
import is the earliest hook the test process has.

After setting the flags we *verify* them statically: a conflicting
XLA_FLAGS inherited from the environment (say --xla_cpu_max_isa=AVX512)
or a backend initialized before this hook would make every parity test
fail with an inscrutable ~1 ulp drift.  check_exact_codegen_env catches
that here, with a message saying what to fix, before any test runs.
"""
import pytest

from repro.explore.device import (check_exact_codegen_env,
                                  ensure_exact_cpu_codegen)

ensure_exact_cpu_codegen()

_problem = check_exact_codegen_env()
if _problem is not None:
  raise pytest.UsageError(
      f"exact-codegen preflight failed: {_problem}.  The bit-identity "
      "parity tests (tests/test_device_sweep.py and friends) cannot pass "
      "in this environment; fix XLA_FLAGS rather than skipping them.")
