"""Tests for the unified repro.explore API: DesignSpace sampling,
evaluation backends (incl. save/load round trip), the columnar
ResultFrame, and the vectorized Pareto front."""
import time

import numpy as np
import pytest

from repro.core import coexplore, dse
from repro.core.workloads import get_network
from repro.explore import (DesignSpace, ExplorationSession, OracleBackend,
                           PolynomialBackend, ResultFrame, pareto_mask,
                           summary_stats)


def brute_force_front(obj: np.ndarray) -> np.ndarray:
  """O(n^2) dominance reference."""
  obj = np.asarray(obj, np.float64)
  n = obj.shape[0]
  mask = np.ones(n, bool)
  for i in range(n):
    dom = np.all(obj <= obj[i], axis=1) & np.any(obj < obj[i], axis=1)
    mask[i] = not dom.any()
  return mask


def legacy_pareto_loop(objectives: np.ndarray) -> np.ndarray:
  """The pre-refactor dse.pareto_front O(n^2) Python loop (perf baseline)."""
  obj = np.asarray(objectives, np.float64)
  n = obj.shape[0]
  mask = np.ones(n, dtype=bool)
  for i in range(n):
    if not mask[i]:
      continue
    dominated_by_i = (np.all(obj >= obj[i], axis=1)
                      & np.any(obj > obj[i], axis=1))
    mask[dominated_by_i] = False
    dominators = (np.all(obj <= obj[i], axis=1)
                  & np.any(obj < obj[i], axis=1))
    if np.any(dominators):
      mask[i] = False
  return mask


@pytest.fixture(scope="module")
def small_backend():
  """Tiny but real fit: 2 PE types, degree 3, 4 layers."""
  layers = get_network("resnet20")[:4]
  return PolynomialBackend.fit(pe_types=("INT16", "LightPE-1"), degree=3,
                               n_train=80, layers=layers, seed=0)


@pytest.fixture(scope="module")
def small_layers():
  return get_network("resnet20")[:4]


class TestDesignSpace:
  def test_sampling_deterministic(self):
    space = DesignSpace()
    assert space.sample(8, seed=5) == space.sample(8, seed=5)
    assert space.sample_type("INT16", 20, seed=1) == \
        space.sample_type("INT16", 20, seed=1)
    assert space.sample_type("INT16", 20, seed=1) != \
        space.sample_type("INT16", 20, seed=2)

  def test_random_matches_legacy_sampler(self):
    """Default-axes random sampling is bit-identical to ppa.sample_configs
    (so refits and cached models stay comparable across the refactor)."""
    from repro.core import ppa
    space = DesignSpace()
    assert space.sample_type("LightPE-2", 40, seed=9) == \
        ppa.sample_configs("LightPE-2", 40, seed=9)

  def test_constraint_filtering(self):
    space = DesignSpace(constraints=[lambda c: c.n_pe <= 256,
                                     lambda c: c.gbuf_kb >= 128])
    cfgs = space.sample_type("INT16", 30, seed=0)
    assert len(cfgs) == 30
    assert all(c.n_pe <= 256 and c.gbuf_kb >= 128 for c in cfgs)

  def test_impossible_constraint_raises(self):
    space = DesignSpace(constraints=[lambda c: False])
    with pytest.raises(ValueError, match="constraints rejected"):
      space.sample_type("INT16", 2, seed=0)

  def test_grid_deterministic_and_unique(self):
    space = DesignSpace()
    a = space.sample_type("INT16", 100, method="grid")
    assert a == space.sample_type("INT16", 100, method="grid")
    assert len(set(a)) == len(a)

  def test_grid_small_space_enumerates_fully(self):
    space = DesignSpace(axes={k: (v[0], v[-1]) for k, v in
                              {"pe_rows": (8, 32), "pe_cols": (8, 32),
                               "sp_if": (6, 64), "sp_fw": (64, 448),
                               "sp_ps": (8, 64), "gbuf_kb": (64, 512),
                               "bandwidth_gbps": (6.4, 25.6)}.items()})
    cfgs = space.sample_type("INT16", 1000, method="grid")
    assert len(cfgs) == 2 ** 7 == space.size() // 4

  def test_stratified_covers_axis_values(self):
    space = DesignSpace()
    n = 9 * 8  # multiple of every axis cardinality's lcm? no: just check
    cfgs = space.sample_type("INT16", n, seed=3, method="stratified")
    assert len(cfgs) == n
    rows = sorted({c.pe_rows for c in cfgs})
    assert rows == sorted(space.axis("pe_rows").values)
    assert cfgs == space.sample_type("INT16", n, seed=3, method="stratified")

  def test_custom_axes_and_size(self):
    space = DesignSpace(pe_types=("INT16",), axes={"gbuf_kb": (64, 128)})
    assert space.axis("gbuf_kb").values == (64, 128)
    cfgs = space.sample_type("INT16", 25, seed=0)
    assert all(c.gbuf_kb in (64, 128) for c in cfgs)
    with pytest.raises(ValueError):
      DesignSpace(axes={"nonsense_axis": (1, 2)})


class TestParetoMask:
  def test_single_point(self):
    assert pareto_mask(np.asarray([[1.0, 2.0]])).tolist() == [True]

  def test_duplicate_points_all_kept(self):
    pts = np.asarray([[1.0, 1.0], [1.0, 1.0], [2.0, 2.0], [1.0, 1.0]])
    assert pareto_mask(pts).tolist() == [True, True, False, True]

  def test_all_dominated_chain(self):
    pts = np.asarray([[3.0, 3.0], [2.0, 2.0], [1.0, 1.0]])
    assert pareto_mask(pts).tolist() == [False, False, True]

  def test_ties_on_one_axis(self):
    # same x: only min-y survives; same y, larger x: dominated
    pts = np.asarray([[1.0, 5.0], [1.0, 4.0], [2.0, 4.0], [0.5, 9.0]])
    assert pareto_mask(pts).tolist() == [False, True, False, True]

  @pytest.mark.parametrize("dim", [1, 2, 3, 4])
  def test_matches_brute_force(self, dim):
    rng = np.random.RandomState(dim)
    for _ in range(4):
      pts = rng.uniform(0, 1, size=(400, dim))
      pts[rng.randint(0, 400, 40)] = pts[rng.randint(0, 400, 40)]
      assert np.array_equal(pareto_mask(pts), brute_force_front(pts))

  def test_empty(self):
    assert pareto_mask(np.zeros((0, 2))).shape == (0,)

  @pytest.mark.slow
  def test_50k_points_exact_and_10x_faster_than_legacy(self):
    """Acceptance: >=50k synthetic points, exact vs the brute-force loop,
    >=10x faster than the old dse.pareto_front implementation."""
    rng = np.random.RandomState(0)
    theta = rng.uniform(0.0, np.pi / 2, 2000)
    arc = np.stack([np.cos(theta), np.sin(theta)], axis=1)  # mutual front
    fill = arc[rng.randint(0, 2000, 48_000)] + rng.uniform(
        0.01, 1.0, size=(48_000, 2))
    pts = np.concatenate([arc, fill])[rng.permutation(50_000)]
    t0 = time.perf_counter()
    fast = pareto_mask(pts)
    t_fast = time.perf_counter() - t0
    t0 = time.perf_counter()
    ref = legacy_pareto_loop(pts)
    t_legacy = time.perf_counter() - t0
    assert np.array_equal(fast, ref)
    assert fast.sum() >= 2000
    assert t_legacy / t_fast >= 10.0, (t_legacy, t_fast)


class TestBackends:
  def test_oracle_backend_matches_characterize(self, small_layers):
    from repro.core import oracle
    cfgs = DesignSpace().sample_type("INT16", 3, seed=0)
    frame = OracleBackend().evaluate(cfgs, small_layers, "net")
    ch = oracle.characterize(cfgs[0], small_layers)
    assert frame.latency_s[0] == ch.latency_s
    assert frame.power_mw[0] == ch.power_mw

  def test_polynomial_matches_legacy_evaluate_with_models(
      self, small_backend, small_layers):
    cfgs = DesignSpace().sample_type("INT16", 10, seed=4) + \
        DesignSpace().sample_type("LightPE-1", 10, seed=5)
    frame = small_backend.evaluate(cfgs, small_layers, "net")
    pts = dse.evaluate_with_models(small_backend.models, cfgs,
                                   small_layers, "net")
    assert np.allclose(frame.latency_s, [p.latency_s for p in pts])
    assert np.allclose(frame.power_mw, [p.power_mw for p in pts])
    assert np.allclose(frame.area_mm2, [p.area_mm2 for p in pts])

  def test_fit_once_in_process_cache(self, small_layers):
    b1 = PolynomialBackend.fit(pe_types=("INT16",), degree=3, n_train=80,
                               layers=small_layers, seed=0)
    b2 = PolynomialBackend.fit(pe_types=("INT16",), degree=3, n_train=80,
                               layers=small_layers, seed=0)
    assert b1.models["INT16"] is b2.models["INT16"]  # no refit

  def test_save_load_roundtrip_bit_identical(self, small_backend,
                                             small_layers, tmp_path):
    path = str(tmp_path / "models.npz")
    small_backend.save(path)
    loaded = PolynomialBackend.load(path)
    assert loaded.pe_types == small_backend.pe_types
    cfgs = DesignSpace().sample_type("INT16", 20, seed=11) + \
        DesignSpace().sample_type("LightPE-1", 20, seed=12)
    a = small_backend.evaluate(cfgs, small_layers, "net")
    b = loaded.evaluate(cfgs, small_layers, "net")
    assert np.array_equal(a.latency_s, b.latency_s)
    assert np.array_equal(a.power_mw, b.power_mw)
    assert np.array_equal(a.area_mm2, b.area_mm2)

  def test_fit_or_load_uses_cache_file(self, small_layers, tmp_path):
    path = str(tmp_path / "cache.npz")
    kw = dict(pe_types=("INT16",), degree=3, n_train=80,
              layers=small_layers, seed=0)
    b1 = PolynomialBackend.fit_or_load(path, **kw)
    assert b1.loaded_from is None  # fitted fresh, then saved
    b2 = PolynomialBackend.fit_or_load(path, **kw)
    assert b2.loaded_from == path
    # changed fit spec -> refit, not a stale cache hit
    b3 = PolynomialBackend.fit_or_load(path, pe_types=("INT16",), degree=3,
                                       n_train=80, layers=small_layers,
                                       seed=1)
    assert b3.loaded_from is None

  def test_fit_or_load_survives_corrupt_cache(self, small_layers, tmp_path):
    path = str(tmp_path / "corrupt.npz")
    with open(path, "wb") as f:
      f.write(b"not an npz file")
    kw = dict(pe_types=("INT16",), degree=3, n_train=80,
              layers=small_layers, seed=0)
    b = PolynomialBackend.fit_or_load(path, **kw)
    assert b.loaded_from is None  # refit, overwrote the corrupt file
    assert PolynomialBackend.fit_or_load(path, **kw).loaded_from == path

  def test_missing_pe_type_raises(self, small_backend, small_layers):
    cfgs = DesignSpace().sample_type("FP32", 2, seed=0)
    with pytest.raises(KeyError, match="FP32"):
      small_backend.evaluate(cfgs, small_layers, "net")


class TestResultFrame:
  @pytest.fixture(scope="class")
  def frame(self, small_backend, small_layers):
    space = DesignSpace(pe_types=("INT16", "LightPE-1"))
    return ExplorationSession(small_backend, space).explore(
        small_layers, "net", n_per_type=40, seed=2)

  def test_points_roundtrip(self, frame):
    back = ResultFrame.from_points(frame.to_points())
    assert np.array_equal(back.latency_s, frame.latency_s)
    assert np.array_equal(back.pe_type, frame.pe_type)
    assert back.cfgs == frame.cfgs

  def test_normalize_matches_legacy(self, frame):
    ppa_n, en_n = frame.normalize(ref="best-int16")
    l_ppa, l_en = dse.normalized_metrics(frame.to_points())
    assert np.allclose(ppa_n, l_ppa)
    assert np.allclose(en_n, l_en)
    ref = frame.reference_index("perf_per_area", "INT16")
    assert frame.pe_type[ref] == "INT16"
    assert ppa_n[ref] == pytest.approx(1.0)

  def test_normalize_requires_int16(self, small_backend, small_layers):
    cfgs = DesignSpace().sample_type("LightPE-1", 4, seed=0)
    fr = small_backend.evaluate(cfgs, small_layers, "net")
    with pytest.raises(ValueError, match="INT16"):
      fr.normalize(ref="best-int16")

  def test_stats_matches_legacy(self, frame):
    assert frame.stats("energy_mj") == \
        dse.distribution_stats(frame.energy_mj)
    m = frame.by_type("INT16")
    assert frame.stats("area_mm2", mask=m) == \
        summary_stats(frame.area_mm2[m])

  def test_top_k(self, frame):
    top = frame.top_k(5, by="perf_per_area")
    assert len(top) == 5
    assert top.perf_per_area[0] == frame.perf_per_area.max()
    assert np.all(np.diff(top.perf_per_area) <= 0)
    worst = frame.top_k(3, by="energy_mj")  # minimized column
    assert worst.energy_mj[0] == frame.energy_mj.min()

  def test_pareto_method(self, frame):
    mask = frame.pareto(cols=("perf_per_area", "energy_mj"))
    obj = np.stack([-frame.perf_per_area, frame.energy_mj], axis=1)
    assert np.array_equal(mask, brute_force_front(obj))

  def test_select_and_concat(self, frame):
    m = frame.by_type("INT16")
    sub = frame.select(m)
    assert len(sub) == int(m.sum())
    assert all(t == "INT16" for t in sub.pe_type)
    both = ResultFrame.concat([sub, frame.select(~m)])
    assert len(both) == len(frame)

  def test_meta_timings(self, frame):
    assert frame.meta["eval_seconds"] > 0
    assert frame.meta["eval_us_per_design"] > 0


class TestSession:
  def test_coexplore_frame_and_shim_agree(self, small_backend):
    import jax
    from repro.core.cnn import sample_arch
    arch_accs = [(sample_arch(jax.random.PRNGKey(0)), 0.8),
                 (sample_arch(jax.random.PRNGKey(1)), 0.6)]
    space = DesignSpace(pe_types=("INT16", "LightPE-1"))
    sess = ExplorationSession(small_backend, space)
    frame = sess.co_explore(arch_accs, n_hw_per_type=4, image_size=16)
    assert len(frame) == 2 * 2 * 4
    assert set(np.unique(frame.extra["top1"])) == {0.6, 0.8}
    pts = coexplore.co_explore(small_backend.models, arch_accs,
                               n_hw_per_type=4, image_size=16,
                               pe_types=("INT16", "LightPE-1"))
    assert len(pts) == len(frame)
    assert [p.latency_s for p in pts] == frame.latency_s.tolist()
    res = coexplore.normalize_and_front(pts)
    assert np.array_equal(
        res["front_energy"], frame.pareto(cols=("top1_err", "energy_mj")))

  def test_session_default_space_follows_backend(self, small_backend):
    sess = ExplorationSession(small_backend)
    assert sess.space.pe_types == small_backend.pe_types
