"""Scalar <-> vector parity for the million-point evaluation path.

Covers ConfigTable round trips, the column-hashed variation term, every
``*_batch`` oracle target (clock/power/area/latency) for every PE type,
``gbuf_overheads``, the VectorOracleBackend acceptance criterion
(<= 1e-9 relative vs OracleBackend on a mixed-PE-type sample), chunking
invariance, the columnar samplers, and hypothesis property tests over
random ConfigTables (skipped cleanly when hypothesis is absent).
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import oracle, ppa
from repro.core.dataflow import (AcceleratorConfig, ConvLayer,
                                 simulate_layer, simulate_layer_batch)
from repro.core.pe import PAPER_PE_TYPES, PE_TYPES
from repro.core.table import ConfigTable
from repro.core.workloads import get_network
from repro.explore import (DesignSpace, ExplorationSession, OracleBackend,
                           PolynomialBackend, VectorOracleBackend,
                           gbuf_overheads, gbuf_overheads_table,
                           vector_constraint)

ALL_TYPES = tuple(PE_TYPES)  # paper's four + INT8/INT4 companions

# every oracle target with a batch sibling: (batch fn, scalar fn)
ORACLE_TARGETS = {
    "clock_mhz": (oracle.clock_mhz_batch, oracle.clock_mhz),
    "pe_area_um2": (oracle.pe_area_um2_batch, oracle.pe_area_um2),
    "array_area_mm2": (oracle.array_area_mm2_batch, oracle.array_area_mm2),
    "gbuf_area_mm2": (oracle.gbuf_area_mm2_batch, oracle.gbuf_area_mm2),
    "area_mm2": (oracle.area_mm2_batch, oracle.area_mm2),
    "leakage_mw": (oracle.leakage_mw_batch, oracle.leakage_mw),
    "array_power_mw": (oracle.array_power_mw_batch, oracle.array_power_mw),
    "gbuf_power_mw": (oracle.gbuf_power_mw_batch, oracle.gbuf_power_mw),
    "power_mw": (oracle.power_mw_batch, oracle.power_mw),
}

EDGE_LAYERS = [
    ConvLayer("conv3", A=32, C=64, F=64, K=3, S=1, P=1),
    ConvLayer("conv1", A=8, C=3, F=1000, K=1),            # 1x1 classifier
    ConvLayer("stride", A=56, C=256, F=512, K=3, S=2, P=1),
    ConvLayer("wide", A=224, C=3, F=64, K=7, S=2, P=3),   # K > many rows
    ConvLayer("tiny", A=1, C=1, F=1, K=1),
]


def mixed_table(n_per_type=20, types=ALL_TYPES, seed0=0):
  cfgs = []
  for i, t in enumerate(types):
    cfgs += ppa.sample_configs(t, n_per_type, seed=seed0 + i)
  return ConfigTable.from_configs(cfgs), cfgs


@pytest.fixture(scope="module")
def small_layers():
  return get_network("resnet20")[:4]


class TestConfigTable:
  def test_round_trip(self):
    tbl, cfgs = mixed_table(8)
    assert tbl.to_configs() == cfgs
    assert tbl.config_at(5) == cfgs[5]
    assert list(tbl.pe_type_strings()) == [c.pe_type for c in cfgs]

  def test_select_concat_chunks(self):
    tbl, cfgs = mixed_table(6)
    idx = np.asarray([0, 3, 11, 17])
    assert tbl.select(idx).to_configs() == [cfgs[i] for i in idx]
    mask = tbl.n_pe <= 256
    assert tbl.select(mask).to_configs() == \
        [c for c in cfgs if c.n_pe <= 256]
    parts = list(tbl.chunks(7))
    assert sum(len(p) for p in parts) == len(tbl)
    assert ConfigTable.concat(parts).to_configs() == cfgs

  def test_pe_const_and_features(self):
    tbl, cfgs = mixed_table(4)
    act = tbl.pe_const("act_bits")
    assert act.tolist() == [float(c.pe.act_bits) for c in cfgs]
    assert np.array_equal(tbl.hw_features(), ppa.hw_feature_matrix(cfgs))
    want = np.asarray([c.latency_hw_features() for c in cfgs])
    assert np.array_equal(tbl.latency_hw_features(), want)

  def test_validation(self):
    with pytest.raises(ValueError, match="missing columns"):
      ConfigTable.from_columns(["INT16"], {"pe_rows": np.asarray([8])})
    with pytest.raises(ValueError, match="unknown PE type"):
      ConfigTable.full("NOPE", 1, {k: np.asarray([8]) for k in
                                   ("pe_rows", "pe_cols", "sp_if", "sp_fw",
                                    "sp_ps", "gbuf_kb", "bandwidth_gbps")})


class TestVariationParity:
  @pytest.mark.parametrize("salt,pct",
                           [("clk", 0.004), ("area", 0.005), ("pwr", 0.005)])
  def test_exact(self, salt, pct):
    tbl, cfgs = mixed_table(25)
    batch = oracle._variation_batch(tbl, salt, pct)
    scalar = np.asarray([oracle._variation(c, salt, pct) for c in cfgs])
    assert np.array_equal(batch, scalar)

  def test_distinct_across_salts_and_rows(self):
    tbl, _ = mixed_table(25)
    a = oracle._variation_batch(tbl, "clk", 0.004)
    b = oracle._variation_batch(tbl, "pwr", 0.004)
    assert not np.array_equal(a, b)
    assert len(np.unique(a)) == len(a)  # no collisions across configs


class TestOracleParity:
  @pytest.mark.parametrize("pe_type", ALL_TYPES)
  def test_all_targets_per_type(self, pe_type):
    cfgs = ppa.sample_configs(pe_type, 20, seed=hash(pe_type) % 1000)
    tbl = ConfigTable.from_configs(cfgs)
    inputs = oracle.batch_inputs(tbl)
    for name, (bfn, sfn) in ORACLE_TARGETS.items():
      batch = bfn(tbl, inputs=inputs)
      scalar = np.asarray([sfn(c) for c in cfgs])
      np.testing.assert_allclose(batch, scalar, rtol=1e-9, err_msg=name)

  def test_mixed_types_bit_identical(self):
    """The numpy batch formulas mirror the scalar ops exactly."""
    tbl, cfgs = mixed_table(15)
    for name, (bfn, sfn) in ORACLE_TARGETS.items():
      assert np.array_equal(
          bfn(tbl), np.asarray([sfn(c) for c in cfgs])), name

  def test_power_area_batch_shares_intermediates(self):
    tbl, _ = mixed_table(12)
    p, a = oracle.power_area_batch(tbl)
    assert np.array_equal(p, oracle.power_mw_batch(tbl))
    assert np.array_equal(a, oracle.area_mm2_batch(tbl))

  def test_gbuf_overheads_table(self):
    tbl, cfgs = mixed_table(10)
    p_s, a_s = gbuf_overheads(cfgs)
    p_t, a_t = gbuf_overheads_table(tbl)
    assert np.array_equal(p_s, p_t)
    assert np.array_equal(a_s, a_t)
    p_d, a_d = gbuf_overheads(tbl)  # table dispatch through the shared API
    assert np.array_equal(p_d, p_t) and np.array_equal(a_d, a_t)


class TestDataflowParity:
  @pytest.mark.parametrize("layer", EDGE_LAYERS, ids=lambda l: l.name)
  def test_simulate_layer_batch(self, layer):
    tbl, cfgs = mixed_table(10)
    clk = oracle.clock_mhz_batch(tbl)
    batch = simulate_layer_batch(tbl, layer, clk)
    fields = ("cycles", "compute_cycles", "dram_stall_cycles", "utilization",
              "spad_reads", "spad_writes", "gbuf_reads", "gbuf_writes",
              "dram_reads", "dram_writes")
    for i, cfg in enumerate(cfgs):
      scalar = simulate_layer(cfg, layer, float(clk[i]))
      assert batch.row(i).macs == scalar.macs
      for f in fields:
        assert float(getattr(batch, f)[i]) == getattr(scalar, f), \
            (layer.name, f, cfg)

  def test_layer_latency_batch(self):
    tbl, cfgs = mixed_table(8)
    for layer in EDGE_LAYERS[:3]:
      batch = oracle.characterize_layer_latency_batch(tbl, layer)
      scalar = [oracle.characterize_layer_latency(c, layer) for c in cfgs]
      np.testing.assert_allclose(batch, scalar, rtol=1e-12)

  def test_characterize_batch(self, small_layers):
    tbl, cfgs = mixed_table(6)
    ch = oracle.characterize_batch(tbl, small_layers)
    for i, cfg in enumerate(cfgs):
      sc = oracle.characterize(cfg, small_layers)
      for f in ("clock_mhz", "area_mm2", "power_mw", "latency_s",
                "energy_mj", "utilization"):
        assert float(getattr(ch, f)[i]) == pytest.approx(
            getattr(sc, f), rel=1e-12), f


class TestVectorOracleBackend:
  def test_acceptance_1k_mixed_within_1e9(self, small_layers):
    """Acceptance: VectorOracleBackend matches OracleBackend within 1e-9
    relative on a 1k-point mixed-PE-type sample."""
    cfgs = DesignSpace().sample(250, seed=42)  # 4 types x 250 = 1000
    assert len(cfgs) == 1000
    fo = OracleBackend().evaluate(cfgs, small_layers, "net")
    fv = VectorOracleBackend().evaluate(cfgs, small_layers, "net")
    for col in ("latency_s", "power_mw", "area_mm2"):
      a, b = getattr(fo, col), getattr(fv, col)
      assert np.max(np.abs(b - a) / np.abs(a)) <= 1e-9, col
    assert list(fo.pe_type) == list(fv.pe_type)
    assert fv.cfgs == fo.cfgs  # list input keeps per-point cfgs

  def test_chunking_invariance(self, small_layers):
    tbl = DesignSpace().sample_table(25, seed=3)
    frames = [VectorOracleBackend(chunk_size=cs).evaluate_table(
        tbl, small_layers) for cs in (1, 7, 64, 10_000)]
    for f in frames[1:]:
      for col in ("latency_s", "power_mw", "area_mm2"):
        assert np.array_equal(getattr(f, col), getattr(frames[0], col)), col

  def test_table_frame_carries_table_not_cfgs(self, small_layers):
    tbl = DesignSpace().sample_table(5, seed=0)
    f = VectorOracleBackend().evaluate_table(tbl, small_layers)
    assert f.cfgs == () and f.table is tbl
    assert f.config_at(2) == tbl.config_at(2)
    top = f.top_k(3, by="perf_per_area")
    assert len(top.table) == 3

  def test_jit_path_exact(self, small_layers):
    """The default x64 device path is bit-identical to numpy (the full
    exactness matrix lives in tests/test_device_sweep.py)."""
    jax = pytest.importorskip("jax")
    del jax
    tbl = DesignSpace().sample_table(10, seed=1)
    base = VectorOracleBackend().evaluate_table(tbl, small_layers)
    jit = VectorOracleBackend(chunk_size=16, jit=True).evaluate_table(
        tbl, small_layers)
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(jit, col), getattr(base, col)), col

  def test_jit_float32_mode_close(self, small_layers):
    """precision="float32" keeps the approximate fast mode."""
    pytest.importorskip("jax")
    tbl = DesignSpace().sample_table(10, seed=1)
    base = VectorOracleBackend().evaluate_table(tbl, small_layers)
    f32 = VectorOracleBackend(chunk_size=16, jit=True,
                              precision="float32").evaluate_table(
        tbl, small_layers)
    for col in ("latency_s", "power_mw", "area_mm2"):
      np.testing.assert_allclose(getattr(f32, col), getattr(base, col),
                                 rtol=1e-3)

  def test_bad_chunk_size(self):
    with pytest.raises(ValueError, match="chunk_size"):
      VectorOracleBackend(chunk_size=0)


class TestPolynomialTablePath:
  def test_table_matches_list(self, small_layers):
    backend = PolynomialBackend.fit(pe_types=("INT16", "LightPE-1"),
                                    degree=3, n_train=80,
                                    layers=small_layers, seed=0)
    space = DesignSpace(pe_types=("INT16", "LightPE-1"))
    cfgs = space.sample(30, seed=9)
    fl = backend.evaluate(cfgs, small_layers, "net")
    ft = backend.evaluate(ConfigTable.from_configs(cfgs), small_layers,
                          "net")
    for col in ("latency_s", "power_mw", "area_mm2"):
      np.testing.assert_allclose(getattr(ft, col), getattr(fl, col),
                                 rtol=1e-12, err_msg=col)
    # chunked table prediction is invariant too
    fc = backend.evaluate_table(ConfigTable.from_configs(cfgs),
                                small_layers, "net", chunk_size=7)
    assert np.allclose(fc.latency_s, ft.latency_s, rtol=1e-12)

  def test_missing_type_raises(self, small_layers):
    backend = PolynomialBackend.fit(pe_types=("INT16",), degree=3,
                                    n_train=80, layers=small_layers, seed=0)
    tbl = DesignSpace().sample_type_table("FP32", 3, seed=0)
    with pytest.raises(KeyError, match="FP32"):
      backend.evaluate(tbl, small_layers, "net")


class TestTableSampling:
  @pytest.mark.parametrize("method", ["grid", "stratified"])
  def test_table_matches_list_sequence(self, method):
    """grid/stratified tables enumerate the exact list-path sequence."""
    space = DesignSpace()
    lst = space.sample_type("LightPE-2", 60, seed=5, method=method)
    tbl = space.sample_type_table("LightPE-2", 60, seed=5, method=method)
    assert tbl.to_configs() == lst

  def test_random_table_deterministic(self):
    space = DesignSpace()
    t1 = space.sample_table(40, seed=8)
    t2 = space.sample_table(40, seed=8)
    assert t1.to_configs() == t2.to_configs()
    assert len(t1) == 40 * len(space.pe_types)
    t3 = space.sample_table(40, seed=9)
    assert t1.to_configs() != t3.to_configs()

  def test_vector_constraints(self):
    space = DesignSpace(constraints=[
        vector_constraint(lambda c: c.n_pe <= 256, lambda t: t.n_pe <= 256)])
    tbl = space.sample_type_table("INT16", 200, seed=0)
    assert len(tbl) == 200 and int(tbl.n_pe.max()) <= 256
    # the same constraint object works on the scalar path
    assert all(c.n_pe <= 256 for c in space.sample_type("INT16", 20, seed=0))

  def test_plain_predicate_fallback(self):
    space = DesignSpace(constraints=[lambda c: c.gbuf_kb >= 128])
    tbl = space.sample_type_table("INT16", 50, seed=0)
    assert len(tbl) == 50 and int(tbl.gbuf_kb.min()) >= 128

  def test_impossible_constraint_raises(self):
    space = DesignSpace(constraints=[
        vector_constraint(lambda c: False,
                          lambda t: np.zeros(len(t), bool))])
    with pytest.raises(ValueError, match="constraints rejected"):
      space.sample_type_table("INT16", 2, seed=0)


class TestFrameMixedRepresentations:
  def test_concat_mixed_cfgs_and_table_keeps_points(self, small_layers):
    """Concat of a table-backed and a cfgs-backed frame lifts the cfgs
    side into the table so design points survive."""
    from repro.explore import ResultFrame
    tbl = DesignSpace().sample_table(3, seed=0)
    f_table = VectorOracleBackend().evaluate_table(tbl, small_layers)
    cfgs = DesignSpace().sample(2, seed=1)
    f_cfgs = OracleBackend().evaluate(cfgs, small_layers, "net")
    both = ResultFrame.concat([f_table, f_cfgs])
    assert len(both) == len(f_table) + len(f_cfgs)
    assert both.table is not None
    pts = both.to_points()
    assert len(pts) == len(both)
    assert pts[-1].cfg == cfgs[-1]
    assert both.config_at(0) == tbl.config_at(0)

  def test_fit_or_load_rejects_stale_oracle_version(self, small_layers,
                                                    tmp_path, monkeypatch):
    """Caches fitted against an older oracle refit instead of loading."""
    path = str(tmp_path / "cache.npz")
    kw = dict(pe_types=("INT16",), degree=3, n_train=80,
              layers=small_layers, seed=0)
    PolynomialBackend.fit_or_load(path, **kw)
    assert PolynomialBackend.fit_or_load(path, **kw).loaded_from == path
    monkeypatch.setattr(oracle, "ORACLE_VERSION", oracle.ORACLE_VERSION + 1)
    from repro.explore import backend as backend_mod
    backend_mod._FIT_CACHE.clear()
    assert PolynomialBackend.fit_or_load(path, **kw).loaded_from is None


class TestSessionVectorized:
  def test_auto_uses_table_for_vector_backend(self, small_layers):
    sess = ExplorationSession(VectorOracleBackend())
    frame = sess.explore(small_layers, "net", n_per_type=10, seed=4)
    assert frame.table is not None and len(frame) == 40
    assert frame.meta["eval_seconds"] > 0

  def test_explicit_vectorized_flag(self, small_layers):
    backend = PolynomialBackend.fit(pe_types=("INT16",), degree=3,
                                    n_train=80, layers=small_layers, seed=0)
    sess = ExplorationSession(backend)
    legacy = sess.explore(small_layers, "net", n_per_type=12, seed=4,
                          vectorized=False)
    assert legacy.table is None  # auto keeps the legacy list path
    table = sess.explore(small_layers, "net", n_per_type=12, seed=4,
                         vectorized=True)
    assert table.table is not None
    with pytest.raises(ValueError, match="evaluate_table"):
      ExplorationSession(OracleBackend()).explore(
          small_layers, "net", n_per_type=2, vectorized=True)


# ---------------------------------------------------------------------------
# property tests (hypothesis optional — skip cleanly without it)
# ---------------------------------------------------------------------------

def _random_table(rng: np.random.RandomState, n: int) -> ConfigTable:
  cols = {name: np.asarray(vals)[rng.randint(0, len(vals), size=n)]
          for name, vals in ppa.HW_RANGES.items()}
  types = np.asarray(list(ALL_TYPES))[rng.randint(0, len(ALL_TYPES), n)]
  return ConfigTable.from_columns(list(types), cols)


class TestProperties:
  @given(st.integers(0, 10_000), st.integers(1, 40))
  @settings(max_examples=20, deadline=None)
  def test_oracle_parity_random_tables(self, seed, n):
    tbl = _random_table(np.random.RandomState(seed), n)
    cfgs = tbl.to_configs()
    for name in ("clock_mhz", "power_mw", "area_mm2"):
      bfn, sfn = ORACLE_TARGETS[name]
      assert np.array_equal(bfn(tbl), np.asarray([sfn(c) for c in cfgs]))

  @given(st.integers(0, 10_000), st.integers(2, 30), st.integers(1, 31))
  @settings(max_examples=10, deadline=None)
  def test_chunking_invariance_random(self, seed, n, chunk):
    tbl = _random_table(np.random.RandomState(seed), n)
    layer = EDGE_LAYERS[seed % len(EDGE_LAYERS)]
    whole = VectorOracleBackend(chunk_size=10_000).evaluate_table(
        tbl, [layer])
    chunked = VectorOracleBackend(chunk_size=chunk).evaluate_table(
        tbl, [layer])
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(whole, col), getattr(chunked, col))

  @given(st.integers(0, 10_000), st.integers(1, 25))
  @settings(max_examples=10, deadline=None)
  def test_latency_parity_random_tables(self, seed, n):
    rng = np.random.RandomState(seed)
    tbl = _random_table(rng, n)
    layer = EDGE_LAYERS[seed % len(EDGE_LAYERS)]
    batch = oracle.characterize_layer_latency_batch(tbl, layer)
    scalar = [oracle.characterize_layer_latency(c, layer)
              for c in tbl.to_configs()]
    np.testing.assert_allclose(batch, scalar, rtol=1e-12)
