"""Optional-import shim for hypothesis.

The property tests use hypothesis when it is installed; without it they
skip cleanly (instead of killing the whole suite at collection time,
which is what a hard ``from hypothesis import ...`` did).

Usage in test modules::

    from hypothesis_compat import given, settings, st
"""
try:
  from hypothesis import given, settings, strategies as st
  HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
  import pytest

  HAVE_HYPOTHESIS = False

  class _AnyStrategy:
    """Stands in for hypothesis.strategies: every attribute / call chain
    (st.integers(0, 5).filter(...), ...) returns another stub.  The values
    are never drawn — @given replaces the test with a skip."""

    def __call__(self, *args, **kwargs):
      return self

    def __getattr__(self, name):
      if name.startswith("__"):
        raise AttributeError(name)
      return self

  st = _AnyStrategy()

  def given(*_args, **_kwargs):
    def decorate(fn):
      # plain (*a, **k) signature on purpose: pytest must not try to
      # resolve the would-be hypothesis-drawn parameters as fixtures
      def skipper(*args, **kwargs):
        pytest.skip("hypothesis not installed (optional extra)")
      skipper.__name__ = fn.__name__
      skipper.__doc__ = fn.__doc__
      return skipper
    return decorate

  def settings(*_args, **_kwargs):
    return lambda fn: fn
