"""Exploration service + result store: admission, deadlines, breaker,
crash-safe store, delta-sweeps, concurrent-session chaos.

The load-bearing invariant everywhere: any path through the service —
interleaved sessions, store hits, delta merges, breaker reroutes,
kill-resume — produces reductions bit-identical to a healthy solo run
(Pareto/TopK frames exactly; stats count/min/max exactly, mean/std to
the documented float tolerance, matching tests/test_streaming.py).
"""
import os
import pickle

import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.cnn import SEARCH_SPACE, ArchChoice
from repro.core.workloads import get_network
from repro.explore import (AdmissionRejected, BudgetExhausted, ChunkTask,
                           CircuitBreaker, Deadline, DeadlineExceeded,
                           DesignSpace, ExplorationService,
                           ExplorationSession, Fault, FaultPlan,
                           HistogramAccumulator, ParetoAccumulator,
                           ResiliencePolicy, ResultStore, RetryPolicy, Rung,
                           SessionCancelled, StatsAccumulator, SweepJournal,
                           SweepKilled, TopKAccumulator,
                           VectorOracleBackend, cached_stream_explore,
                           stream_explore)
from repro.explore.space import AXIS_ORDER, HW_RANGES
from repro.explore.streaming import default_explore_reducers

METRICS = ("latency_s", "power_mw", "area_mm2")
NETWORK = "resnet20"


def no_wait() -> RetryPolicy:
  return RetryPolicy(sleep=lambda s: None)


@pytest.fixture(scope="module")
def layers():
  return get_network(NETWORK)[:4]


@pytest.fixture(scope="module")
def arch_accs():
  rng = np.random.RandomState(7)
  archs = [ArchChoice(tuple((int(rng.choice(r)), int(rng.choice(c)))
                            for r, c in SEARCH_SPACE)) for _ in range(4)]
  return list(zip(archs, rng.uniform(0.5, 0.95, len(archs))))


def backend():
  return VectorOracleBackend(chunk_size=256)


def sweep_reducers():
  return {"pareto": ParetoAccumulator(("latency_s", "power_mw")),
          "top": TopKAccumulator(9, by="power_mw"),
          "stats": StatsAccumulator("latency_s"),
          "hist": HistogramAccumulator("power_mw", 0.0, 5e4, bins=32)}


def small_grid_space(extra_on=None):
  """A few-hundred-point grid space; ``extra_on`` grows one axis by one
  value (an in-order supersequence — the delta-sweep precondition)."""
  axes = {name: HW_RANGES[name][:2] for name in AXIS_ORDER}
  axes[AXIS_ORDER[0]] = HW_RANGES[AXIS_ORDER[0]][:3]
  if extra_on is not None:
    axes[extra_on] = HW_RANGES[extra_on][:len(axes[extra_on]) + 1]
  return DesignSpace(axes=axes)


def assert_frames_equal(got, want):
  for name in ("pareto", "top"):
    for col in METRICS[:2]:
      assert np.array_equal(getattr(got[name], col),
                            getattr(want[name], col)), (name, col)


def assert_stats_equal(got, want):
  # the repo-wide streaming contract: count/min/max exact, mean/std to
  # float tolerance across different chunk partitions
  gs, ws = got["stats"], want["stats"]
  assert gs["count"] == ws["count"]
  assert gs["min"] == ws["min"] and gs["max"] == ws["max"]
  assert_allclose(gs["mean"], ws["mean"], rtol=1e-12)
  assert_allclose(gs["std"], ws["std"], rtol=1e-9)
  assert np.array_equal(got["hist"]["counts"], want["hist"]["counts"])


# ---------------------------------------------------------------------------
# Deadline
# ---------------------------------------------------------------------------

class TestDeadline:

  def test_fake_clock(self):
    t = {"now": 100.0}
    dl = Deadline(5.0, clock=lambda: t["now"])
    assert dl.remaining() == 5.0 and not dl.expired()
    t["now"] = 104.0
    assert dl.remaining() == pytest.approx(1.0)
    t["now"] = 105.0
    assert dl.expired()

  def test_real_clock_counts_down(self):
    dl = Deadline(60.0)
    assert 0.0 < dl.remaining() <= 60.0
    assert not dl.expired()


# ---------------------------------------------------------------------------
# circuit breaker (unit level, fake rungs)
# ---------------------------------------------------------------------------

def device_task(index, device_fn, host="host"):
  return ChunkTask(index, (Rung("device", device_fn, layer="device"),
                           Rung("numpy", lambda: host)))


class TestCircuitBreaker:

  def test_opens_after_consecutive_failures(self):
    br = CircuitBreaker(threshold=2, cooldown=3, jitter=0)
    br.allow_device(); br.record_failure()
    assert br.state == "closed"
    br.allow_device(); br.record_failure()
    assert br.state == "open" and br.n_opens == 1

  def test_success_resets_failure_streak(self):
    br = CircuitBreaker(threshold=2, cooldown=3, jitter=0)
    br.record_failure()
    br.record_success()
    br.record_failure()
    assert br.state == "closed"  # streak broken: 1+1 non-consecutive

  def test_open_short_circuits_device_rung(self):
    br = CircuitBreaker(threshold=1, cooldown=10, jitter=0)
    pol = ResiliencePolicy(retry=no_wait(), breaker=br)
    calls = {"n": 0}

    def dead():
      calls["n"] += 1
      raise RuntimeError("wedged")

    assert pol.execute(device_task(0, dead)) == "host"
    assert br.state == "open"
    n_after_open = calls["n"]
    # while open, the device fn is never invoked again
    assert pol.execute(device_task(1, dead)) == "host"
    assert calls["n"] == n_after_open
    assert pol.n_demotions == 1  # only the opening chunk paid a demotion

  def test_cooldown_probe_success_closes(self):
    br = CircuitBreaker(threshold=1, cooldown=2, jitter=0)
    br.allow_device(); br.record_failure()
    assert br.state == "open"
    assert not br.allow_device()      # cooldown 2 -> 1
    assert br.allow_device()          # cooldown exhausted: the probe
    assert br.state == "half-open" and br.n_probes == 1
    br.record_success()
    assert br.state == "closed"

  def test_probe_failure_reopens(self):
    br = CircuitBreaker(threshold=1, cooldown=1, jitter=0)
    br.allow_device(); br.record_failure()
    assert br.allow_device()          # immediate half-open probe
    br.record_failure()
    assert br.state == "open" and br.n_opens == 2

  def test_transitions_and_meta(self):
    br = CircuitBreaker(threshold=1, cooldown=1, jitter=0)
    br.allow_device(); br.record_failure()
    br.allow_device(); br.record_success()
    states = [(f, t) for _, f, t in br.transitions]
    assert states == [("closed", "open"), ("open", "half-open"),
                      ("half-open", "closed")]
    meta = br.meta()
    assert meta["breaker_state"] == "closed"
    assert meta["n_breaker_opens"] == 1.0
    assert meta["n_breaker_probes"] == 1.0

  def test_seeded_jitter_is_deterministic(self):
    def opens(seed):
      br = CircuitBreaker(threshold=1, cooldown=2, jitter=3, seed=seed)
      br.record_failure()
      n = 0
      while not br.allow_device():
        n += 1
      return n
    assert opens(0) == opens(0)

  def test_validation(self):
    with pytest.raises(ValueError):
      CircuitBreaker(threshold=0)
    with pytest.raises(ValueError):
      CircuitBreaker(cooldown=0)


# ---------------------------------------------------------------------------
# result store: atomic writes, checksums, quarantine
# ---------------------------------------------------------------------------

class TestResultStore:

  def test_roundtrip(self, tmp_path):
    store = ResultStore(tmp_path)
    store.put("k1", {"done": {1, 2}, "n_rows": 7})
    assert "k1" in store
    assert store.get("k1") == {"done": {1, 2}, "n_rows": 7}
    assert store.stats()["n_hits"] == 1

  def test_miss_counts(self, tmp_path):
    store = ResultStore(tmp_path)
    assert store.get("nope") is None
    assert store.stats() == {"n_hits": 0, "n_misses": 1,
                             "n_quarantined": 0}

  def test_no_tmp_file_left(self, tmp_path):
    store = ResultStore(tmp_path)
    store.put("k1", {"x": 1})
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]

  @pytest.mark.parametrize("damage", ["truncate", "flip", "garbage"])
  def test_corruption_quarantined(self, tmp_path, damage):
    store = ResultStore(tmp_path)
    store.put("k1", {"x": list(range(100))})
    path = store.path("k1")
    blob = open(path, "rb").read()
    if damage == "truncate":
      open(path, "wb").write(blob[:len(blob) // 2])
    elif damage == "flip":
      open(path, "wb").write(blob[:-3] + bytes([blob[-3] ^ 0xFF])
                             + blob[-2:])
    else:
      open(path, "wb").write(b"not a store entry at all")
    assert store.get("k1") is None       # detected, not trusted
    assert "k1" not in store             # moved aside
    assert store.stats()["n_quarantined"] == 1
    assert os.listdir(store.quarantine_dir)  # evidence preserved
    store.put("k1", {"x": 1})            # recompute path works
    assert store.get("k1") == {"x": 1}

  def test_wrong_key_payload_rejected(self, tmp_path):
    # an entry whose embedded key disagrees with its filename slot is
    # not served (defends against file-level tampering/misplacement)
    store = ResultStore(tmp_path)
    store.put("aaaa", {"x": 1})
    os.replace(store.path("aaaa"), store.path("bbbb"))
    assert store.get("bbbb") is None

  def test_manifest_index(self, tmp_path):
    store = ResultStore(tmp_path)
    store.put_final("k1", {"x": 1}, manifest={"kind": "explore", "v": 1})
    store.put_final("k2", {"x": 2}, manifest={"kind": "explore", "v": 2})
    store.put_final("k1", {"x": 3}, manifest={"kind": "explore", "v": 3})
    entries = store.manifests()
    assert [e["key"] for e in entries] == ["k1", "k2"]
    assert entries[0]["v"] == 3  # last write wins per key

  def test_compact_manifests_keeps_latest_per_key(self, tmp_path):
    store = ResultStore(tmp_path)
    for v in range(5):
      store.put_final("k1", {"x": v}, manifest={"v": v})
    store.put_final("k2", {"x": 9}, manifest={"v": 9})
    before = store.manifests()
    assert store.compact_manifests() == 4   # four superseded k1 entries
    assert store.compact_manifests() == 0   # idempotent
    after = store.manifests()
    assert sorted((e["key"], e["v"]) for e in after) == \
        sorted((e["key"], e["v"]) for e in before)
    # the log itself shrank to exactly one frame per key
    raw = store._journal.replay(store.INDEX_KEY)
    assert len(raw) == 2

  def test_concurrent_writers_two_processes(self, tmp_path):
    # two child processes hammer put_final on the same store; the fcntl
    # manifest lock must serialize the append-log writes so every entry
    # survives intact (no torn/interleaved frames dropped by replay)
    import subprocess
    import sys
    import textwrap
    n_each = 40
    script = textwrap.dedent("""
        import sys
        from repro.explore import ResultStore
        store = ResultStore(sys.argv[1])
        who, n = sys.argv[2], int(sys.argv[3])
        for i in range(n):
            store.put_final(f"{who}-{i:04d}", {"x": i},
                            manifest={"who": who, "i": i})
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    procs = [subprocess.Popen(
        [sys.executable, "-c", script, str(tmp_path), who, str(n_each)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE)
        for who in ("a", "b")]
    for p in procs:
      _, err = p.communicate(timeout=120)
      assert p.returncode == 0, err.decode()[-2000:]
    store = ResultStore(tmp_path)
    entries = store.manifests()
    assert len(entries) == 2 * n_each     # nothing torn, nothing lost
    for who in ("a", "b"):
      got = sorted(e["i"] for e in entries if e["who"] == who)
      assert got == list(range(n_each))
    # and every stored result is readable
    assert store.get("a-0000") == {"x": 0}
    assert store.get(f"b-{n_each - 1:04d}") == {"x": n_each - 1}


# ---------------------------------------------------------------------------
# append-log journal: kill-mid-append recovery
# ---------------------------------------------------------------------------

def _state(n):
  return {"done": set(range(n)), "reducers": {}, "counters": {"n_rows": n}}


class TestJournalLog:

  def test_append_replay_roundtrip(self, tmp_path):
    j = SweepJournal(tmp_path)
    for n in (1, 2, 3):
      j.append("k", _state(n))
    states = j.replay("k")
    assert [len(s["done"]) for s in states] == [1, 2, 3]
    assert j.load_last("k")["counters"]["n_rows"] == 3

  def test_kill_mid_append_recovers_prefix(self, tmp_path):
    # simulate a process killed partway through an append: a valid log
    # followed by a torn frame.  Recovery = every complete record, the
    # torn tail truncated, and the log writable again.
    j = SweepJournal(tmp_path)
    j.append("k", _state(1))
    j.append("k", _state(2))
    intact = os.path.getsize(j.log_path("k"))
    with open(j.log_path("k"), "ab") as f:
      f.write(b"SWPJ" + b"\x99")  # header torn mid-write
    states = j.replay("k")
    assert [len(s["done"]) for s in states] == [1, 2]
    assert os.path.getsize(j.log_path("k")) == intact  # tail truncated
    j.append("k", _state(3))  # appending after recovery works
    assert len(j.replay("k")) == 3

  @pytest.mark.parametrize("tear", ["payload", "digest", "garbage"])
  def test_torn_tail_variants(self, tmp_path, tear):
    j = SweepJournal(tmp_path)
    j.append("k", _state(1))
    good = open(j.log_path("k"), "rb").read()
    if tear == "payload":
      torn = good + good[:len(good) - 5]   # header ok, payload short
    elif tear == "digest":
      bad = bytearray(good)
      bad[len(b"SWPJ") + 8] ^= 0xFF        # digest byte flipped
      torn = good + bytes(bad)
    else:
      torn = good + b"\x00" * 7
    open(j.log_path("k"), "wb").write(torn)
    states = j.replay("k")
    assert len(states) == 1
    assert os.path.getsize(j.log_path("k")) == len(good)

  def test_corruption_mid_log_drops_suffix(self, tmp_path):
    # a bad record invalidates everything after it (framing is lost) —
    # the valid prefix is still a safe resume point
    j = SweepJournal(tmp_path)
    for n in (1, 2, 3):
      j.append("k", _state(n))
    blob = bytearray(open(j.log_path("k"), "rb").read())
    blob[len(blob) // 3] ^= 0xFF
    open(j.log_path("k"), "wb").write(bytes(blob))
    states = j.replay("k")
    assert 0 < len(states) < 3

  def test_load_state_prefers_more_progress(self, tmp_path):
    j = SweepJournal(tmp_path)
    j.record("k", _state(5))     # pkl snapshot: 5 chunks
    j.append("k", _state(2))     # log lags behind
    assert len(j.load_state("k")["done"]) == 5
    j.append("k", _state(9))     # log pulls ahead
    assert len(j.load_state("k")["done"]) == 9


# ---------------------------------------------------------------------------
# the service: admission, fairness, deadlines, budgets, store hits
# ---------------------------------------------------------------------------

def submit_sweep(svc, space, layers, seed=1, n=1200, **kw):
  return svc.submit_explore(space, layers, NETWORK, n_per_type=n,
                            seed=seed, chunk_size=256,
                            reducers=sweep_reducers(), **kw)


def solo_sweep(space, layers, seed=1, n=1200):
  return stream_explore(backend(), space, layers, network=NETWORK,
                        n_per_type=n, seed=seed, chunk_size=256,
                        reducers=sweep_reducers(), workers=2)


class TestService:

  def test_concurrent_sessions_match_solo(self, layers):
    space = DesignSpace()
    refs = [solo_sweep(space, layers, seed=s) for s in (1, 2, 3)]
    svc = ExplorationService(backend(), slots=3)
    handles = [submit_sweep(svc, space, layers, seed=s) for s in (1, 2, 3)]
    assert svc.drain() == 3
    for h, ref in zip(handles, refs):
      res = h.result()
      assert_frames_equal(res, ref)
      assert_stats_equal(res, ref)
      assert res.n_rows == ref.n_rows

  def test_fair_interleaving(self, layers):
    # with fewer slots than sessions the queue drains through the slots;
    # every session still completes with full results
    space = DesignSpace()
    ref = solo_sweep(space, layers, seed=1)
    svc = ExplorationService(backend(), slots=2, max_queued=8)
    handles = [submit_sweep(svc, space, layers, seed=1) for _ in range(4)]
    assert svc.drain() == 4
    for h in handles:
      assert_frames_equal(h.result(), ref)

  def test_admission_rejected_typed(self, layers):
    space = DesignSpace()
    svc = ExplorationService(backend(), slots=1, max_queued=1)
    submit_sweep(svc, space, layers, seed=1)
    submit_sweep(svc, space, layers, seed=2)
    with pytest.raises(AdmissionRejected) as err:
      submit_sweep(svc, space, layers, seed=3)
    assert err.value.queued == 1 and err.value.max_queued == 1
    assert svc.service_meta()["n_rejected"] == 1
    assert svc.drain() == 2  # admitted work unaffected

  def test_budget_exhausted_then_resumed(self, layers, tmp_path):
    space = DesignSpace()
    ref = solo_sweep(space, layers, seed=1, n=3000)
    svc = ExplorationService(backend(), slots=1, store=str(tmp_path))
    h = submit_sweep(svc, space, layers, seed=1, n=3000, chunk_budget=3)
    svc.drain()
    with pytest.raises(BudgetExhausted):
      h.result()
    assert h.status == "failed"
    # resubmit without the budget: resumes from the journal
    svc2 = ExplorationService(backend(), slots=1, store=str(tmp_path))
    h2 = submit_sweep(svc2, space, layers, seed=1, n=3000)
    svc2.drain()
    res = h2.result()
    assert res.meta["n_resumed_chunks"] == 3.0
    assert_frames_equal(res, ref)
    assert_stats_equal(res, ref)

  def test_deadline_expiry_spares_neighbors(self, layers):
    space = DesignSpace()
    ref = solo_sweep(space, layers, seed=2)
    t = {"now": 0.0}
    svc = ExplorationService(backend(), slots=2)
    doomed = submit_sweep(svc, space, layers, seed=1, n=3000,
                          deadline=Deadline(5.0, clock=lambda: t["now"]))
    healthy = submit_sweep(svc, space, layers, seed=2)
    t["now"] = 10.0
    svc.drain()
    with pytest.raises(DeadlineExceeded):
      doomed.result()
    assert doomed.status == "expired"
    assert_frames_equal(healthy.result(), ref)  # neighbor unpoisoned

  def test_deadline_threads_into_resolve_timeout(self):
    # the per-session policy's watchdog budget is min(base, remaining)
    t = {"now": 0.0}
    svc = ExplorationService(backend(), resolve_timeout=60.0)
    pol = svc._session_policy(Deadline(5.0, clock=lambda: t["now"]))
    assert pol.resolve_timeout() == 5.0
    t["now"] = 3.0
    assert pol.resolve_timeout() == pytest.approx(2.0)
    t["now"] = 99.0
    assert pol.resolve_timeout() == 0.0  # expired: watchdog fires at once

  def test_cancel_is_cooperative(self, layers):
    space = DesignSpace()
    svc = ExplorationService(backend(), slots=1)
    h = submit_sweep(svc, space, layers, seed=1)
    h.cancel()
    svc.drain()
    with pytest.raises(SessionCancelled):
      h.result()
    assert h.status == "cancelled"

  def test_store_hit_bit_identical(self, layers, tmp_path):
    space = DesignSpace()
    svc = ExplorationService(backend(), slots=1, store=str(tmp_path))
    h1 = submit_sweep(svc, space, layers, seed=1)
    svc.drain()
    ref = h1.result()
    h2 = submit_sweep(svc, space, layers, seed=1)  # no drain needed
    res = h2.result()
    assert res.meta["store_hit"] == 1.0
    assert_frames_equal(res, ref)
    assert_stats_equal(res, ref)
    assert svc.service_meta()["n_store_hits"] == 1

  def test_store_hits_bypass_admission(self, layers, tmp_path):
    # a hit consumes no executor time, so it is never queue-rejected
    space = DesignSpace()
    svc = ExplorationService(backend(), slots=1, max_queued=1,
                             store=str(tmp_path))
    h = submit_sweep(svc, space, layers, seed=1)
    svc.drain()
    h.result()
    submit_sweep(svc, space, layers, seed=2)
    submit_sweep(svc, space, layers, seed=3)  # queue now full
    hit = submit_sweep(svc, space, layers, seed=1)
    assert hit.status == "done"

  def test_background_thread_mode(self, layers):
    space = DesignSpace()
    ref = solo_sweep(space, layers, seed=1)
    svc = ExplorationService(backend(), slots=2)
    svc.start()
    try:
      h = submit_sweep(svc, space, layers, seed=1)
      assert_frames_equal(h.result(timeout=120.0), ref)
    finally:
      svc.stop()

  def test_result_timeout_is_bounded(self, layers):
    space = DesignSpace()
    svc = ExplorationService(backend(), slots=1)
    h = submit_sweep(svc, space, layers, seed=1)  # nothing drives it
    with pytest.raises(TimeoutError):
      h.result(timeout=0.2)

  def test_search_session_matches_solo(self, layers):
    space = DesignSpace()
    sess = ExplorationSession(backend(), space)
    ref = sess.optimize(layers=layers, network=NETWORK, population=12,
                        generations=3, seed=9)
    svc = ExplorationService(backend(), slots=2)
    hs = svc.submit_search(space, layers, network=NETWORK, population=12,
                           generations=3, seed=9)
    he = submit_sweep(svc, space, layers, seed=1)
    svc.drain()
    res = hs.result()
    for col in METRICS[:2]:
      assert np.array_equal(getattr(res["pareto"], col),
                            getattr(ref["pareto"], col)), col
    assert he.result().n_rows > 0

  def test_search_deadline_cancels_cooperatively(self, layers):
    space = DesignSpace()
    t = {"now": 0.0}
    svc = ExplorationService(backend(), slots=1)
    h = svc.submit_search(space, layers, network=NETWORK, population=12,
                          generations=50, seed=9,
                          deadline=Deadline(5.0, clock=lambda: t["now"]))
    t["now"] = 10.0
    svc.drain()
    with pytest.raises(DeadlineExceeded):
      h.result()
    assert h.status == "expired"

  def test_co_explore_sessions(self, layers, arch_accs, tmp_path):
    from repro.explore.streaming import stream_co_explore
    space = DesignSpace()
    cols = ("top1_err", "energy_mj", "area_mm2")
    co_red = lambda: {"pareto": ParetoAccumulator(cols)}  # noqa: E731
    ref = stream_co_explore(backend(), space, arch_accs, n_hw_per_type=10,
                            seed=3, image_size=16, reducers=co_red(),
                            chunk_size=64, workers=2)
    svc = ExplorationService(backend(), slots=2, store=str(tmp_path))
    h = svc.submit_co_explore(space, arch_accs, n_hw_per_type=10, seed=3,
                              image_size=16, reducers=co_red(),
                              chunk_size=64)
    svc.drain()
    res = h.result()
    for col in METRICS:
      assert np.array_equal(getattr(res["pareto"], col),
                            getattr(ref["pareto"], col)), col
    assert np.array_equal(res["pareto"].extra["arch_id"],
                          ref["pareto"].extra["arch_id"])
    # and a store hit on resubmission
    h2 = svc.submit_co_explore(space, arch_accs, n_hw_per_type=10, seed=3,
                               image_size=16, reducers=co_red(),
                               chunk_size=64)
    assert h2.result().meta["store_hit"] == 1.0


# ---------------------------------------------------------------------------
# delta-sweeps: one-axis edits evaluate only the new subgrid
# ---------------------------------------------------------------------------

GRID_N = 10**9  # "the whole grid", whatever its size


def grid_sweep(svc, space, layers, chunk_size=128):
  return svc.submit_explore(space, layers, NETWORK, n_per_type=GRID_N,
                            method="grid", chunk_size=chunk_size,
                            reducers=sweep_reducers())


class TestDeltaSweep:

  @pytest.mark.parametrize("axis,chunks", [
      (AXIS_ORDER[1], (128, 64, 256)),
      (AXIS_ORDER[4], (96, 128, 32)),    # different axis position
      (AXIS_ORDER[6], (64, 32, 128)),    # last (fastest-varying) axis
  ])
  def test_delta_bit_identical_across_partitions(self, layers, tmp_path,
                                                 axis, chunks):
    """The acceptance property: base-sweep + delta over the new subgrid
    == from-scratch over the edited space, bit-identically, regardless
    of how any of the three sweeps was chunked."""
    c_base, c_delta, c_scratch = chunks
    base, edited = small_grid_space(), small_grid_space(extra_on=axis)
    svc = ExplorationService(backend(), slots=1, store=str(tmp_path))
    grid_sweep(svc, base, layers, chunk_size=c_base)
    svc.drain()
    hd = grid_sweep(svc, edited, layers, chunk_size=c_delta)
    svc.drain()
    res = hd.result()
    assert res.meta["delta_sweep"] == 1.0
    assert res.meta["n_delta_rows"] < res.n_rows  # only the subgrid ran
    scratch = stream_explore(backend(), edited, layers, network=NETWORK,
                             n_per_type=GRID_N, method="grid",
                             reducers=sweep_reducers(),
                             chunk_size=c_scratch, workers=2)
    assert res.n_rows == scratch.n_rows
    assert_frames_equal(res, scratch)
    assert_stats_equal(res, scratch)

  def test_delta_result_is_stored_and_chains(self, layers, tmp_path):
    # a delta-sweep's merged result is itself a stored full-grid sweep:
    # a second axis edit deltas off the *merged* entry
    a1, a2 = AXIS_ORDER[1], AXIS_ORDER[5]
    base = small_grid_space()
    edited1 = small_grid_space(extra_on=a1)
    axes2 = {a.name: a.values for a in edited1.axes}
    axes2[a2] = tuple(HW_RANGES[a2][:len(axes2[a2]) + 1])
    edited2 = DesignSpace(axes=axes2)
    svc = ExplorationService(backend(), slots=1, store=str(tmp_path))
    grid_sweep(svc, base, layers)
    svc.drain()
    grid_sweep(svc, edited1, layers)
    svc.drain()
    h = grid_sweep(svc, edited2, layers)
    svc.drain()
    res = h.result()
    assert res.meta["delta_sweep"] == 1.0
    scratch = stream_explore(backend(), edited2, layers, network=NETWORK,
                             n_per_type=GRID_N, method="grid",
                             reducers=sweep_reducers(), chunk_size=128)
    assert_frames_equal(res, scratch)
    assert_stats_equal(res, scratch)

  def test_corrupt_base_falls_back_to_full_sweep(self, layers, tmp_path):
    axis = AXIS_ORDER[1]
    base, edited = small_grid_space(), small_grid_space(extra_on=axis)
    svc = ExplorationService(backend(), slots=1, store=str(tmp_path))
    grid_sweep(svc, base, layers)
    svc.drain()
    # corrupt every stored result: the delta base is discovered via the
    # manifest but fails verification -> quarantined -> full sweep
    for name in os.listdir(tmp_path):
      if name.startswith("result-"):
        open(os.path.join(tmp_path, name), "wb").write(b"rot")
    h = grid_sweep(svc, edited, layers)
    svc.drain()
    res = h.result()
    assert "delta_sweep" not in res.meta
    scratch = stream_explore(backend(), edited, layers, network=NETWORK,
                             n_per_type=GRID_N, method="grid",
                             reducers=sweep_reducers(), chunk_size=128)
    assert_frames_equal(res, scratch)

  def test_unrelated_spaces_do_not_delta(self, layers, tmp_path):
    # two axes changed: not a single-axis edit, no delta
    base = small_grid_space()
    edited = small_grid_space(extra_on=AXIS_ORDER[1])
    axes = {a.name: a.values for a in edited.axes}
    axes[AXIS_ORDER[2]] = tuple(HW_RANGES[AXIS_ORDER[2]][:3])
    both = DesignSpace(axes=axes)
    svc = ExplorationService(backend(), slots=1, store=str(tmp_path))
    grid_sweep(svc, base, layers)
    svc.drain()
    h = grid_sweep(svc, both, layers)
    svc.drain()
    assert "delta_sweep" not in h.result().meta

  def test_cached_driver_and_session_wiring(self, layers, tmp_path):
    """The standalone cached driver and the ``store=`` session argument
    route through the same store semantics as the service."""
    axis = AXIS_ORDER[1]
    base, edited = small_grid_space(), small_grid_space(extra_on=axis)
    store = ResultStore(tmp_path)
    r1 = cached_stream_explore(backend(), base, layers, network=NETWORK,
                               n_per_type=GRID_N, method="grid",
                               reducers=sweep_reducers(), chunk_size=128,
                               workers=2, store=store)
    assert "store_hit" not in r1.meta or r1.meta.get("store_hit") != 1.0
    sess = ExplorationSession(backend(), edited)
    r2 = sess.explore(layers, NETWORK, n_per_type=GRID_N, method="grid",
                      stream=True, reducers=sweep_reducers(),
                      chunk_size=96, store=store)
    assert r2.meta["delta_sweep"] == 1.0
    scratch = stream_explore(backend(), edited, layers, network=NETWORK,
                             n_per_type=GRID_N, method="grid",
                             reducers=sweep_reducers(), chunk_size=128)
    assert_frames_equal(r2, scratch)
    assert_stats_equal(r2, scratch)
    # and the session store= path serves hits
    r3 = sess.explore(layers, NETWORK, n_per_type=GRID_N, method="grid",
                      stream=True, reducers=sweep_reducers(),
                      chunk_size=96, store=store)
    assert r3.meta["store_hit"] == 1.0
    assert_frames_equal(r3, scratch)

  def test_store_requires_stream(self, layers, tmp_path):
    sess = ExplorationSession(backend())
    with pytest.raises(ValueError, match="stream=True"):
      sess.explore(layers, NETWORK, store=ResultStore(tmp_path))


# ---------------------------------------------------------------------------
# chaos: concurrent sessions under injected faults, kills, sick devices
# ---------------------------------------------------------------------------

class _DeadDeviceBackend:
  """A jit-shaped backend whose device rungs always fail — numpy path
  delegates to the real vector oracle, so demoted results stay exact."""

  name = "dead-device"
  jit = True
  prefers_table = True

  def __init__(self):
    self._inner = VectorOracleBackend(chunk_size=256)
    self.n_device_calls = 0

  def evaluate_table(self, table, layers, network="net"):
    return self._inner.evaluate_table(table, layers, network)

  def fused_eval_pending(self, chunk, layers, network, plan, idx):
    self.n_device_calls += 1
    raise RuntimeError("device runtime wedged")

  def eval_pending(self, chunk, layers, network, idx):
    self.n_device_calls += 1
    raise RuntimeError("device runtime wedged")


class TestServiceChaos:

  def test_sessions_race_under_faults_bit_identical(self, layers):
    space = DesignSpace()
    refs = {s: solo_sweep(space, layers, seed=s) for s in (1, 2, 3)}
    # times=2 < the retry budget's 3 attempts: every fault heals in place
    plan = FaultPlan.seeded(seed=11, n_chunks=12, p_raise=0.4,
                            layer="task", times=2)
    svc = ExplorationService(backend(), slots=3, retry=no_wait(),
                             fault_plan=plan)
    handles = {s: submit_sweep(svc, space, layers, seed=s)
               for s in (1, 2, 3)}
    assert svc.drain() == 3
    for s, h in handles.items():
      res = h.result()
      assert_frames_equal(res, refs[s])
      assert_stats_equal(res, refs[s])
    assert plan.n_fired > 0  # the chaos actually happened

  def test_kill_mid_drain_then_resume(self, layers, tmp_path):
    space = DesignSpace()
    refs = {s: solo_sweep(space, layers, seed=s, n=2500)
            for s in (1, 2)}
    plan = FaultPlan([Fault("kill", 4, "task")])
    svc = ExplorationService(backend(), slots=2, store=str(tmp_path),
                             fault_plan=plan)
    h1 = submit_sweep(svc, space, layers, seed=1, n=2500)
    h2 = submit_sweep(svc, space, layers, seed=2, n=2500)
    with pytest.raises(SweepKilled):
      svc.drain()
    assert h1.status == "failed" and h2.status == "failed"
    # a fresh service over the same store replays the journaled chunks
    svc2 = ExplorationService(backend(), slots=2, store=str(tmp_path))
    g1 = submit_sweep(svc2, space, layers, seed=1, n=2500)
    g2 = submit_sweep(svc2, space, layers, seed=2, n=2500)
    svc2.drain()
    for g, s in ((g1, 1), (g2, 2)):
      res = g.result()
      assert res.meta["n_resumed_chunks"] > 0
      assert_frames_equal(res, refs[s])
      assert_stats_equal(res, refs[s])

  def test_sick_device_opens_breaker(self, layers):
    """Persistently failing device rungs open the shared breaker: later
    chunks route straight to numpy (no more device calls, no more
    demotion spend) and results stay bit-identical."""
    space = DesignSpace()
    ref = solo_sweep(space, layers, seed=1, n=4000)
    dead = _DeadDeviceBackend()
    br = CircuitBreaker(threshold=2, cooldown=1000, jitter=0)
    svc = ExplorationService(dead, slots=1, retry=no_wait(), breaker=br)
    h = submit_sweep(svc, space, layers, seed=1, n=4000)
    svc.drain()
    res = h.result()
    assert res.meta["breaker_state"] == "open"
    assert res.meta["n_breaker_opens"] == 1.0
    assert res.meta["n_breaker_short_circuits"] > 0
    assert any(f == "closed" and t == "open"
               for _, f, t in res.meta["breaker_transitions"])
    # the breaker bounded the blast radius: device calls stop after the
    # opening chunks instead of failing once per chunk
    assert dead.n_device_calls < res.meta["n_chunks"] * 2
    assert res.meta["n_demotions"] < res.meta["n_chunks"]
    assert_frames_equal(res, ref)
    assert_stats_equal(res, ref)

  def test_breaker_shared_across_sessions(self, layers):
    # session B inherits the breaker state session A's failures opened
    space = DesignSpace()
    dead = _DeadDeviceBackend()
    br = CircuitBreaker(threshold=2, cooldown=10_000, jitter=0)
    svc = ExplorationService(dead, slots=1, retry=no_wait(), breaker=br)
    ha = submit_sweep(svc, space, layers, seed=1)
    svc.drain()
    calls_after_a = dead.n_device_calls
    hb = submit_sweep(svc, space, layers, seed=2)
    svc.drain()
    assert dead.n_device_calls == calls_after_a  # B never touched it
    assert hb.result().meta["breaker_state"] == "open"
    assert_frames_equal(ha.result(), solo_sweep(space, layers, seed=1))
