"""Training substrate tests: optimizer, train step, checkpoints, trainer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.data.synthetic import (DataCursor, MarkovTokenStream,
                                  TokenStreamConfig, token_batches)
from repro.models.model import build_model
from repro.quant.policy import QuantPolicy
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


class TestAdamW:
  @pytest.mark.slow
  def test_quadratic_convergence(self):
    cfg = opt_lib.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                              schedule="constant", grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt_lib.adamw_init(cfg, params)
    for _ in range(300):
      g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
      params, state, _ = opt_lib.adamw_update(cfg, params, g, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2

  def test_quantized_state_tracks_full(self):
    """int8-state AdamW follows full-precision AdamW closely."""
    params_a = {"w": jnp.ones((512,)) * 2.0}
    params_b = {"w": jnp.ones((512,)) * 2.0}
    cfg_a = opt_lib.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                                schedule="constant", grad_clip=0.0)
    cfg_b = opt_lib.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                                schedule="constant", grad_clip=0.0,
                                quantize_state=True)
    sa = opt_lib.adamw_init(cfg_a, params_a)
    sb = opt_lib.adamw_init(cfg_b, params_b)
    key = KEY
    for i in range(50):
      key = jax.random.fold_in(key, i)
      g = {"w": params_a["w"] + 0.1 * jax.random.normal(key, (512,))}
      params_a, sa, _ = opt_lib.adamw_update(cfg_a, params_a, g, sa)
      g2 = {"w": params_b["w"] + 0.1 * jax.random.normal(key, (512,))}
      params_b, sb, _ = opt_lib.adamw_update(cfg_b, params_b, g2, sb)
    diff = float(jnp.max(jnp.abs(params_a["w"] - params_b["w"])))
    assert diff < 0.05, diff

  def test_grad_clip(self):
    cfg = opt_lib.AdamWConfig(grad_clip=1.0)
    g = {"w": jnp.ones((4,)) * 100.0}
    norm = opt_lib.global_norm(g)
    assert float(norm) == pytest.approx(200.0)


class TestSGDRecipe:
  def test_paper_lr_schedule(self):
    """lr 0.1 dropped 5x at epochs 60/120/160 (paper Sec 4.3)."""
    cfg = opt_lib.SGDConfig(steps_per_epoch=10)
    assert float(opt_lib.sgd_lr_at(cfg, jnp.asarray(0))) == \
        pytest.approx(0.1)
    assert float(opt_lib.sgd_lr_at(cfg, jnp.asarray(600))) == \
        pytest.approx(0.02)
    assert float(opt_lib.sgd_lr_at(cfg, jnp.asarray(1200))) == \
        pytest.approx(0.004)
    assert float(opt_lib.sgd_lr_at(cfg, jnp.asarray(1600))) == \
        pytest.approx(0.0008)


class TestTrainStep:
  def _setup(self, **tkw):
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    model = build_model(cfg)
    tkw.setdefault("optimizer", opt_lib.AdamWConfig(
        lr=3e-3, warmup_steps=0, schedule="constant", weight_decay=0.0))
    tcfg = ts_lib.TrainConfig(**tkw)
    state = ts_lib.make_train_state(model, tcfg, KEY)
    return cfg, model, tcfg, state

  @pytest.mark.slow
  def test_loss_decreases(self):
    cfg, model, tcfg, state = self._setup()
    stream = MarkovTokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                                 branching=4))
    step = ts_lib.jit_train_step(model, tcfg, donate=False)
    losses = []
    for i in range(30):
      toks, labels = stream.sample_batch(8, 64, i)
      state, m = step(state, {"tokens": jnp.asarray(toks),
                              "labels": jnp.asarray(labels)})
      losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses

  @pytest.mark.slow
  def test_microbatch_equivalence(self):
    """grad accumulation over 2 microbatches ~ single big batch."""
    cfg, model, tcfg1, state1 = self._setup(microbatches=1)
    _, _, tcfg2, state2 = self._setup(microbatches=2)
    batch = {"tokens": jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size)}
    s1, m1 = ts_lib.train_step(model, tcfg1, state1, batch)
    s2, m2 = ts_lib.train_step(model, tcfg2, state2, batch)
    w1 = s1["params"]["embed"]
    w2 = s2["params"]["embed"]
    assert float(jnp.max(jnp.abs(w1 - w2))) < 5e-3

  @pytest.mark.slow
  def test_qat_policy_trains(self):
    cfg, model, tcfg, state = self._setup(
        quant=QuantPolicy(pe_type="LightPE-2"))
    batch = {"tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)}
    state2, m = ts_lib.train_step(model, tcfg, state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    # params actually changed
    assert float(jnp.max(jnp.abs(
        state2["params"]["embed"] - state["params"]["embed"]))) > 0


class TestCheckpoint:
  def test_atomic_roundtrip(self, tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.asarray(7)}}
    ckpt_lib.save_checkpoint(str(tmp_path), 7, state,
                             extra={"data_step": 9})
    step, restored, extra = ckpt_lib.restore_checkpoint(str(tmp_path))
    assert step == 7 and extra["data_step"] == 9
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(6.0).reshape(2, 3))

  def test_keep_last_k(self, tmp_path):
    state = {"w": jnp.zeros(2)}
    for s in range(6):
      ckpt_lib.save_checkpoint(str(tmp_path), s, state, keep=2)
    assert ckpt_lib.list_checkpoints(str(tmp_path)) == [4, 5]

  def test_partial_write_ignored(self, tmp_path):
    state = {"w": jnp.zeros(2)}
    ckpt_lib.save_checkpoint(str(tmp_path), 1, state)
    # a crash mid-write leaves an .npz with no manifest -> ignored
    open(os.path.join(str(tmp_path), "ckpt_00000002.npz"), "wb").write(b"x")
    assert ckpt_lib.list_checkpoints(str(tmp_path)) == [1]


class TestTrainerResume:
  def test_restart_resumes_exactly(self, tmp_path):
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    model = build_model(cfg)
    tcfg = ts_lib.TrainConfig()
    stream = MarkovTokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size))

    def batches(cursor):
      return token_batches(stream, 4, 32, cursor)

    tr_cfg = TrainerConfig(total_steps=6, ckpt_every=3, log_every=100,
                           ckpt_dir=str(tmp_path))
    c1 = DataCursor()
    t1 = Trainer(model, tcfg, tr_cfg, batches(c1), cursor=c1, key=KEY)
    t1.run(6)
    # "crash" after step 6 (ckpt at step 6); restart from checkpoint
    c2 = DataCursor()
    t2 = Trainer(model, tcfg, tr_cfg, batches(c2), cursor=c2, key=KEY)
    assert t2.maybe_restore()
    assert t2.step == 6
    assert c2.step == 6  # data cursor resumed
    w1 = t1.state["params"]["embed"]
    w2 = t2.state["params"]["embed"]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2))
