"""Device-resident sweep path: exactness, fused reducers, kernels.

Covers the PR-5 acceptance matrix:
  * x64 ``jit=True`` device evaluation is bit-identical to the numpy
    path (plain and joint, chunked);
  * fused on-device reducers fold to bit-identical Pareto/top-k frames
    (and identical histograms) versus the host-reducer stream, across
    shuffled chunk partitions and versus the one-shot frame;
  * the Pallas dominance-count kernel matches its pure-jnp ref in
    interpret mode;
  * satellite guards: the jit-program LRU stays bounded, the float32
    mode stays approximate-only, survivor-cap overflow falls back to
    exact full-chunk folds.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.cnn import SEARCH_SPACE, ArchChoice
from repro.core.dataflow import LayerStack
from repro.core.workloads import get_network
from repro.explore import (DesignSpace, ExplorationSession,
                           VectorOracleBackend)
from repro.explore.backend import _LRUCache
from repro.explore.streaming import (HistogramAccumulator,
                                     ParetoAccumulator, StatsAccumulator,
                                     TopKAccumulator, run_stream,
                                     stream_co_explore, stream_explore)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

METRICS = ("latency_s", "power_mw", "area_mm2")


@pytest.fixture(scope="module")
def layers():
  return get_network("resnet20")[:5]


@pytest.fixture(scope="module")
def space():
  return DesignSpace()


@pytest.fixture(scope="module")
def arch_accs():
  from repro.core.supernet import arch_to_layers
  rng = np.random.RandomState(7)
  archs = [ArchChoice(tuple((int(rng.choice(r)), int(rng.choice(c)))
                            for r, c in SEARCH_SPACE)) for _ in range(9)]
  accs = rng.uniform(0.5, 0.95, size=len(archs))
  # keep arch_to_layers importable once for the stack fixture below
  del arch_to_layers
  return list(zip(archs, accs))


@pytest.fixture(scope="module")
def stack(arch_accs):
  from repro.core.supernet import arch_to_layers
  lists = [arch_to_layers(a, image_size=16) for a, _ in arch_accs]
  lists[-1] = lists[-1][:3]  # ragged stack: exercises the validity mask
  return LayerStack.from_layer_lists(lists)


class TestExactDeviceEval:
  def test_plain_bit_identity(self, layers, space):
    tbl = space.sample_table(120, seed=11)
    base = VectorOracleBackend().evaluate_table(tbl, layers)
    dev = VectorOracleBackend(chunk_size=47, jit=True).evaluate_table(
        tbl, layers)
    for col in METRICS:
      assert np.array_equal(getattr(dev, col), getattr(base, col)), col

  def test_joint_bit_identity(self, stack, space):
    hw = space.sample_table(19, seed=5)
    base = VectorOracleBackend().co_evaluate_table(hw, stack)
    dev = VectorOracleBackend(chunk_size=130, jit=True).co_evaluate_table(
        hw, stack)
    for col in METRICS:
      assert np.array_equal(getattr(dev, col), getattr(base, col)), col
    assert np.array_equal(dev.extra["arch_id"], base.extra["arch_id"])

  def test_parity_max_rel_err_is_zero(self, layers, space):
    """The acceptance-criterion formulation: max relative error == 0."""
    tbl = space.sample_table(80, seed=2)
    base = VectorOracleBackend().evaluate_table(tbl, layers)
    dev = VectorOracleBackend(jit=True).evaluate_table(tbl, layers)
    rel = max(float(np.max(np.abs(getattr(dev, c) / getattr(base, c) - 1.0)))
              for c in METRICS)
    assert rel == 0.0

  def test_dedup_matches_stack_joint(self, stack, space):
    """The distinct-layer factorization is bit-identical on numpy too."""
    from repro.core import oracle
    hw = space.sample_table(11, seed=9)
    ref = oracle.characterize_joint(hw, stack)
    unique_cols, slot_ids = stack.dedup_slots()
    got = oracle.characterize_joint_dedup(hw, unique_cols, slot_ids,
                                          stack.valid)
    for col in ("latency_s", "energy_mj", "power_mw", "area_mm2",
                "utilization"):
      assert np.array_equal(getattr(ref, col), getattr(got, col)), col

  def test_float32_mode_is_approximate_only(self, layers, space):
    tbl = space.sample_table(40, seed=3)
    base = VectorOracleBackend().evaluate_table(tbl, layers)
    f32 = VectorOracleBackend(jit=True, precision="float32").evaluate_table(
        tbl, layers)
    for col in METRICS:
      np.testing.assert_allclose(getattr(f32, col), getattr(base, col),
                                 rtol=1e-3)

  def test_bad_precision_rejected(self):
    with pytest.raises(ValueError, match="precision"):
      VectorOracleBackend(precision="f16")


def _reducers():
  return {"pareto": ParetoAccumulator(),
          "top": TopKAccumulator(9, by="energy_mj"),
          "stats": StatsAccumulator("power_mw"),
          "hist": HistogramAccumulator("area_mm2", 0.0, 200.0, bins=32)}


def _joint_reducers():
  return {"pareto": ParetoAccumulator(("top1_err", "energy_mj",
                                       "area_mm2")),
          "top": TopKAccumulator(9, by="energy_mj")}


def _assert_frames_equal(a, b, ctx=""):
  for col in METRICS:
    assert np.array_equal(a.column(col), b.column(col)), (ctx, col)
  assert set(a.extra) == set(b.extra), ctx
  for k in a.extra:
    assert np.array_equal(a.extra[k], b.extra[k]), (ctx, k)


class TestFusedReducers:
  def test_plain_fused_matches_host(self, layers, space):
    host = stream_explore(VectorOracleBackend(), space, layers,
                          n_per_type=90, seed=4, reducers=_reducers(),
                          chunk_size=53)
    dev = stream_explore(VectorOracleBackend(jit=True), space, layers,
                         n_per_type=90, seed=4, reducers=_reducers(),
                         chunk_size=53)
    _assert_frames_equal(dev["pareto"], host["pareto"], "pareto")
    _assert_frames_equal(dev["top"], host["top"], "top")
    assert np.array_equal(dev["hist"]["counts"], host["hist"]["counts"])
    for k, v in host["stats"].items():
      assert dev["stats"][k] == pytest.approx(v, rel=1e-12), k

  def test_joint_fused_matches_host_and_one_shot(self, arch_accs, space):
    cols = ("top1_err", "energy_mj", "area_mm2")
    host = stream_co_explore(VectorOracleBackend(), space, arch_accs,
                             n_hw_per_type=13, seed=3, image_size=16,
                             reducers=_joint_reducers(), chunk_size=41)
    dev = stream_co_explore(VectorOracleBackend(jit=True), space, arch_accs,
                            n_hw_per_type=13, seed=3, image_size=16,
                            reducers=_joint_reducers(), chunk_size=41)
    _assert_frames_equal(dev["pareto"], host["pareto"], "pareto")
    _assert_frames_equal(dev["top"], host["top"], "top")
    # ... and both match the one-shot frame's pareto/top_k row for row
    session = ExplorationSession(VectorOracleBackend(), space)
    frame = session.co_explore(arch_accs, n_hw_per_type=13, seed=3,
                               image_size=16)
    want_front = frame.select(frame.pareto(cols))
    want_top = frame.top_k(9, by="energy_mj")
    for col in METRICS:
      assert np.array_equal(dev["pareto"].column(col),
                            want_front.column(col)), col
      assert np.array_equal(dev["top"].column(col),
                            want_top.column(col)), col

  def test_shuffled_partition_invariance(self, layers, space):
    """Fused chunks fold to the same state for any chunk partition and
    any fold order — the streaming engine's core invariant, exercised
    through run_stream directly with shuffled device tasks."""
    backend = VectorOracleBackend(jit=True)
    from repro.explore.device import build_plan
    tbl = space.sample_table(70, seed=8)
    base = VectorOracleBackend().evaluate_table(tbl, layers)
    want_front = base.select(base.pareto(("perf_per_area", "energy_mj")))
    want_top = base.top_k(9, by="energy_mj")

    rng = np.random.RandomState(0)
    for trial in range(3):
      reducers = _reducers()
      plan = build_plan(reducers, joint=False)
      assert plan is not None
      # random contiguous partition, then shuffled task order
      cuts = np.sort(rng.choice(np.arange(1, len(tbl)), size=4,
                                replace=False))
      bounds = [0, *cuts.tolist(), len(tbl)]
      pieces = [(tbl.select(slice(lo, hi)),
                 np.arange(lo, hi, dtype=np.int64))
                for lo, hi in zip(bounds[:-1], bounds[1:])]
      rng.shuffle(pieces)
      tasks = [
          (lambda chunk=c, idx=i: backend.fused_eval_pending(
              chunk, layers, "net", plan, idx)) for c, i in pieces]
      res = run_stream(iter(tasks), reducers)
      for col in METRICS:
        assert np.array_equal(res["pareto"].column(col),
                              want_front.column(col)), (trial, col)
        assert np.array_equal(res["top"].column(col),
                              want_top.column(col)), (trial, col)

  def test_survivor_cap_overflow_falls_back_exactly(self, layers, space):
    """A cap below the true front size forces the full-frame fallback;
    results stay exact.  The 3-objective columns also exercise the
    generic block-prefilter path (>= 3 variable objectives)."""
    from repro.explore import device as device_lib
    backend = VectorOracleBackend(jit=True)
    cols = ("latency_s", "power_mw", "area_mm2")
    tbl = space.sample_table(60, seed=6)
    base = VectorOracleBackend().evaluate_table(tbl, layers)
    want = base.select(base.pareto(cols))
    assert len(want) > 1  # otherwise cap=front-1 below cannot overflow
    reducers = {"pareto": ParetoAccumulator(cols)}
    plan = device_lib.build_plan(reducers, joint=False, cap=len(want) - 1)
    pend = backend.fused_eval_pending(tbl, layers, "net", plan,
                                      np.arange(len(tbl), dtype=np.int64))
    chunk = pend.resolve()
    kind, frame, idx = chunk.payloads["pareto"]
    assert kind == "rows" and len(frame) == len(tbl)  # full-chunk fallback
    reducers["pareto"].fold_payload(chunk.payloads["pareto"])
    got = reducers["pareto"].result()
    assert len(got) == len(want)
    for col in METRICS:
      assert np.array_equal(got.column(col), want.column(col)), col

  def test_collect_reducer_is_not_fusable(self):
    from repro.explore.device import build_plan
    from repro.explore.streaming import CollectAccumulator
    assert build_plan({"frame": CollectAccumulator()}, joint=False) is None

  def test_auto_stream_device_frame_identical(self, layers, space):
    """The non-fused pending path (CollectAccumulator route) returns the
    identical full frame."""
    from repro.explore.streaming import CollectAccumulator
    host = stream_explore(VectorOracleBackend(), space, layers,
                          n_per_type=40, seed=12,
                          reducers={"frame": CollectAccumulator()},
                          chunk_size=37)
    dev = stream_explore(VectorOracleBackend(jit=True), space, layers,
                         n_per_type=40, seed=12,
                         reducers={"frame": CollectAccumulator()},
                         chunk_size=37)
    _assert_frames_equal(dev["frame"], host["frame"], "collect")


class TestParetoFrontKernel:
  """Interpret-mode correctness of the Pallas dominance kernel."""

  @pytest.mark.parametrize("n,d", [(64, 2), (300, 3), (513, 4)])
  def test_counts_match_ref(self, n, d):
    from repro.kernels.pareto_front import ops
    from repro.kernels.pareto_front.ref import dominance_counts_ref
    rng = np.random.RandomState(n + d)
    obj = rng.uniform(size=(n, d)).astype(np.float32)
    obj[n // 3] = obj[2 * n // 3]  # duplicates: dominate nobody
    got = np.asarray(ops.dominance_counts(obj, interpret=True))
    want = np.asarray(dominance_counts_ref(obj))
    assert np.array_equal(got, want)

  def test_front_matches_host_pareto(self):
    from repro.explore.frame import pareto_mask
    from repro.kernels.pareto_front import ops
    rng = np.random.RandomState(0)
    obj = rng.uniform(size=(400, 3)).astype(np.float32)
    got = np.asarray(ops.pareto_front_mask(obj, interpret=True))
    assert np.array_equal(got, pareto_mask(obj.astype(np.float64)))

  @pytest.mark.parametrize("use_pallas", [False, True])
  def test_block_prefilter_is_front_superset(self, use_pallas):
    from repro.explore.frame import pareto_mask
    from repro.kernels.pareto_front import ops
    from repro.kernels.pareto_front.ref import block_dominance_counts_ref
    import jax.numpy as jnp
    rng = np.random.RandomState(1)
    obj = rng.uniform(size=(500, 3)).astype(np.float32)
    mask = np.asarray(ops.block_prefilter_mask(obj, block=128,
                                               use_pallas=use_pallas,
                                               interpret=True))
    front = pareto_mask(obj.astype(np.float64))
    assert not (front & ~mask).any()  # no front point is ever dropped
    # blockwise counts agree with the blockwise ref on padded input
    pad = np.full((12, 3), np.inf, np.float32)
    padded = jnp.asarray(np.concatenate([obj, pad]))
    want = np.asarray(block_dominance_counts_ref(padded, 128))
    got_pallas = np.asarray(ops.block_prefilter_mask(
        padded, block=128, use_pallas=True, interpret=True))
    assert np.array_equal(got_pallas, want == 0)

  def test_staircase_prefilter_is_front_superset(self):
    from repro.explore.device import _staircase_mask
    from repro.explore.frame import pareto_mask
    import jax.numpy as jnp
    rng = np.random.RandomState(2)
    x = rng.uniform(size=(5, 200))
    y = rng.uniform(size=(5, 200))
    keep = np.asarray(_staircase_mask(jnp.asarray(x), jnp.asarray(y),
                                      jnp, jax))
    for g in range(5):
      front = pareto_mask(np.stack([x[g], y[g]], axis=1))
      assert not (front & ~keep[g]).any(), g


class TestInterleavedSearchGenerations:
  """Guided-search generations interleave distinct fused plans through
  one backend: every generation must stay exact while the jit LRU churns,
  and cap overflow must degrade to the full-chunk fold, never to a wrong
  front."""

  def test_distinct_plans_stay_exact_under_lru_churn(self, layers, space):
    from repro.explore import device as device_lib
    backend = VectorOracleBackend(jit=True)
    cols = ("perf_per_area", "energy_mj")
    n_gens = backend.JIT_CACHE_SIZE + 3  # > maxsize: forces eviction
    overflow_hit = fused_hit = False
    for g in range(n_gens):
      tbl = space.sample_table(40, seed=100 + g)
      base = VectorOracleBackend().evaluate_table(tbl, layers)
      want = base.select(base.pareto(cols))
      if g == 0:
        assert len(want) > 1  # otherwise cap below cannot overflow
        cap = len(want) - 1   # generation 0: guaranteed overflow
      else:
        cap = len(tbl) + g    # distinct plan per generation, no overflow
      reducers = {"pareto": ParetoAccumulator(cols)}
      plan = device_lib.build_plan(reducers, joint=False, cap=cap)
      pend = backend.fused_eval_pending(tbl, layers, "net", plan,
                                        np.arange(len(tbl), dtype=np.int64))
      chunk = pend.resolve()
      kind, frame, _ = chunk.payloads["pareto"]
      assert kind == "rows"
      if cap < len(want):
        overflow_hit = True
        assert len(frame) == len(tbl)  # full-chunk fallback
      else:
        fused_hit = True
        assert len(frame) <= cap       # O(survivors) transfer
      reducers["pareto"].fold_payload(chunk.payloads["pareto"])
      got = reducers["pareto"].result()
      for col in METRICS:
        assert np.array_equal(got.column(col), want.column(col)), (g, col)
      assert len(backend._jit_cache) <= backend.JIT_CACHE_SIZE
    assert overflow_hit and fused_hit
    # 11 distinct plans passed through an 8-entry cache: it is full, and
    # eviction actually happened (the earliest plans are gone)
    assert len(backend._jit_cache) == backend.JIT_CACHE_SIZE

  def test_device_optimize_matches_numpy_optimize(self, layers, space):
    """The search trajectory itself is bit-identical across backends:
    every generation's fitness feeds selection, so one differing ulp
    would diverge the whole run."""
    kw = dict(objectives=("perf_per_area", "energy_mj"), population=12,
              generations=4, seed=5)
    host = ExplorationSession(VectorOracleBackend(), space).optimize(
        layers, **kw)
    dev = ExplorationSession(VectorOracleBackend(chunk_size=32, jit=True),
                             space).optimize(layers, **kw)
    assert host.n_rows == dev.n_rows
    a, b = host["pareto"], dev["pareto"]
    for col in ("perf_per_area", "energy_mj") + METRICS:
      assert np.array_equal(a.column(col), b.column(col)), col
    assert np.array_equal(a.table.pe_rows, b.table.pe_rows)
    assert list(a.pe_type) == list(b.pe_type)

  def test_fused_stats_single_row_chunk_has_zero_m2(self, layers, space):
    """Device mirror of StatsAccumulator's n == 1 short-circuit: a
    single-row chunk's fused stats payload carries M2 == 0.0 (a NaN here
    would poison every downstream Welford merge)."""
    from repro.explore import device as device_lib
    backend = VectorOracleBackend(jit=True)
    tbl = space.sample_type_table(space.pe_types[0], 1, seed=13)
    reducers = {"stats": StatsAccumulator("power_mw")}
    plan = device_lib.build_plan(reducers, joint=False)
    pend = backend.fused_eval_pending(tbl, layers, "net", plan,
                                      np.zeros(1, np.int64))
    kind, payload = pend.resolve().payloads["stats"]
    assert kind == "stats"
    assert payload["n"] == 1
    assert payload["m2"] == 0.0
    assert payload["min"] == payload["max"] == payload["mean"]
    # folding it must leave the accumulator NaN-free and mergeable
    reducers["stats"].fold_payload(("stats", payload))
    base = VectorOracleBackend().evaluate_table(tbl, layers)
    got = reducers["stats"].result()
    assert got["mean"] == float(base.power_mw[0])
    assert got["std"] == 0.0


class TestJitCacheBound:
  def test_lru_evicts_oldest(self):
    cache = _LRUCache(maxsize=2)
    cache.put("a", 1)
    cache.put("b", 2)
    assert cache.get("a") == 1  # refresh a
    cache.put("c", 3)           # evicts b
    assert cache.get("b") is None
    assert cache.get("a") == 1 and cache.get("c") == 3
    assert len(cache) == 2

  def test_backend_cache_stays_bounded(self, space):
    """Sweeping many distinct networks must not leak executables."""
    backend = VectorOracleBackend(chunk_size=32, jit=True)
    tbl = space.sample_type_table(space.pe_types[0], 4, seed=0)
    nets = get_network("resnet20")
    for i in range(backend.JIT_CACHE_SIZE + 3):
      backend.evaluate_table(tbl, nets[i:i + 2], f"net{i}")
    assert len(backend._jit_cache) <= backend.JIT_CACHE_SIZE
