"""Regression pins for the compat shims (core/dse.py, core/coexplore.py).

These are the paper-reproduction entry points; each test computes the
expected answer through the public ``repro.explore`` path and asserts
the shim's output matches bit-for-bit — front membership AND ordering —
so refactors of the explore package can't silently drift them.
"""
import numpy as np
import pytest

from repro.core import coexplore, dse
from repro.core.supernet import SEARCH_SPACE, ArchChoice
from repro.core.table import ConfigTable
from repro.core.workloads import get_network
from repro.explore import (DesignSpace, ExplorationSession, OracleBackend,
                           PolynomialBackend, ResultFrame,
                           VectorOracleBackend, pareto_mask, summary_stats)

PE_TYPES = ("INT8", "INT16")  # INT16 present: the normalization anchor


@pytest.fixture(scope="module")
def layers():
  return get_network("resnet20")[:3]


@pytest.fixture(scope="module")
def backend(layers):
  # small fit; the process-wide fit cache makes reruns free
  return PolynomialBackend.fit(PE_TYPES, degree=3, n_train=40,
                               layers=layers, seed=0)


@pytest.fixture(scope="module")
def cfgs():
  space = DesignSpace(pe_types=PE_TYPES)
  return space.sample(6, seed=1)  # 6 per type, both PE types


@pytest.fixture(scope="module")
def arch_accs():
  rng = np.random.RandomState(7)
  out = []
  for i in range(3):
    arch = ArchChoice(tuple(
        (int(rng.choice(reps)), int(rng.choice(chs)))
        for reps, chs in SEARCH_SPACE))
    out.append((arch, 0.55 + 0.1 * i))
  return out


class TestDseShim:

  def test_pareto_front_is_pareto_mask(self):
    rng = np.random.RandomState(0)
    for d in (2, 3):
      obj = rng.rand(64, d)
      got = dse.pareto_front(obj)
      assert np.array_equal(got, pareto_mask(obj))
      # semantic pin: a kept row is dominated by no other row
      for i in np.flatnonzero(got):
        dom = np.all(obj <= obj[i], axis=1) & np.any(obj < obj[i], axis=1)
        assert not dom.any()

  def test_evaluate_with_oracle_pins_explore_path(self, cfgs, layers):
    pts = dse.evaluate_with_oracle(cfgs, layers, "net")
    frame = OracleBackend().evaluate(cfgs, layers, "net")
    vec = VectorOracleBackend().evaluate_table(
        ConfigTable.from_configs(cfgs), layers, "net")
    assert [p.cfg for p in pts] == list(cfgs)  # ordering preserved
    for col, attr in (("latency_s", "latency_s"), ("power_mw", "power_mw"),
                      ("area_mm2", "area_mm2")):
      got = np.asarray([getattr(p, attr) for p in pts])
      assert np.array_equal(got, frame.column(col))
      # scalar shim == vectorized table path, bit for bit (PR-2 contract)
      assert np.array_equal(got, vec.column(col))

  def test_evaluate_with_models_pins_table_path(self, backend, cfgs, layers):
    pts = dse.evaluate_with_models(backend.models, cfgs, layers, "net")
    frame = backend.evaluate_table(ConfigTable.from_configs(cfgs), layers,
                                   "net")
    assert [p.cfg for p in pts] == list(cfgs)
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(
          np.asarray([getattr(p, col) for p in pts]), frame.column(col))

  def test_best_int16_reference_pins_reference_index(self, backend, cfgs,
                                                     layers):
    pts = dse.evaluate_with_models(backend.models, cfgs, layers, "net")
    ref = dse.best_int16_reference(pts)
    frame = ResultFrame.from_points(pts)
    assert ref is pts[frame.reference_index("perf_per_area")]
    assert ref.cfg.pe_type == "INT16"
    int16 = [p for p in pts if p.cfg.pe_type == "INT16"]
    assert ref.perf_per_area == max(p.perf_per_area for p in int16)

  def test_normalized_metrics_pins_frame_normalize(self, backend, cfgs,
                                                   layers):
    pts = dse.evaluate_with_models(backend.models, cfgs, layers, "net")
    ppa_n, energy_n = dse.normalized_metrics(pts)
    norm = ResultFrame.from_points(pts).normalize(ref="best-int16")
    assert np.array_equal(ppa_n, norm.perf_per_area)
    assert np.array_equal(energy_n, norm.energy)
    # explicit-ref variant pins the tuple-ref path
    ref = dse.best_int16_reference(pts)
    ppa_r, energy_r = dse.normalized_metrics(pts, ref=ref)
    assert np.array_equal(ppa_r, ppa_n)
    assert np.array_equal(energy_r, energy_n)

  def test_distribution_stats_pins_summary_stats(self):
    v = np.random.RandomState(4).rand(101)
    assert dse.distribution_stats(v) == summary_stats(v)


class TestCoexploreShim:

  @pytest.fixture(scope="class")
  def pts(self, backend, arch_accs):
    return coexplore.co_explore(backend.models, arch_accs, n_hw_per_type=4,
                                seed=3, image_size=16, pe_types=PE_TYPES)

  def test_co_explore_pins_session_path(self, backend, arch_accs, pts):
    session = ExplorationSession(backend, DesignSpace(pe_types=PE_TYPES))
    frame = session.co_explore(arch_accs, n_hw_per_type=4, seed=3,
                               image_size=16, vectorized=False)
    assert len(pts) == len(frame)
    lookup = frame.arch_lookup
    assert np.array_equal(
        np.asarray([p.latency_s for p in pts]), frame.latency_s)
    assert np.array_equal(
        np.asarray([p.power_mw for p in pts]), frame.power_mw)
    assert np.array_equal(
        np.asarray([p.area_mm2 for p in pts]), frame.area_mm2)
    assert np.array_equal(
        np.asarray([p.top1 for p in pts]), frame.extra["top1"])
    # row order: (pe_type, arch, hw) loop order, arch identity via lookup
    assert [p.cfg.pe_type for p in pts] == list(frame.pe_type)
    assert [p.arch for p in pts] \
        == [lookup[int(a)] for a in frame.extra["arch_id"]]

  def test_copoint_derived_fields(self, pts):
    for p in pts[:8]:
      assert p.energy_mj == p.power_mw * p.latency_s
      assert p.top1_err == 1.0 - p.top1

  def test_normalize_and_front_pins_explore_ops(self, pts):
    d = coexplore.normalize_and_front(pts)
    # expected, via the public explore surface on the same rows
    frame = ResultFrame(
        latency_s=np.asarray([p.latency_s for p in pts]),
        power_mw=np.asarray([p.power_mw for p in pts]),
        area_mm2=np.asarray([p.area_mm2 for p in pts]),
        pe_type=np.asarray([p.cfg.pe_type for p in pts]),
        extra={"top1": np.asarray([p.top1 for p in pts], np.float64)})
    e_ref = frame.energy_mj[frame.reference_index("energy")]
    a_ref = frame.area_mm2[frame.reference_index("area")]
    err = frame.column("top1_err")
    energy = frame.energy_mj / e_ref
    area = frame.area_mm2 / a_ref
    assert np.array_equal(d["err"], err)
    assert np.array_equal(d["energy"], energy)
    assert np.array_equal(d["area"], area)
    assert np.array_equal(d["types"], frame.pe_type)
    assert np.array_equal(d["front_energy"],
                          pareto_mask(np.stack([err, energy], axis=1)))
    assert np.array_equal(d["front_area"],
                          pareto_mask(np.stack([err, area], axis=1)))
    # membership sanity: every front point is genuinely non-dominated
    obj = np.stack([err, energy], axis=1)
    for i in np.flatnonzero(d["front_energy"]):
      dom = np.all(obj <= obj[i], axis=1) & np.any(obj < obj[i], axis=1)
      assert not dom.any()
