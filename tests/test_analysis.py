"""repro.analysis: engine mechanics + one seeded regression per rule.

Fixture trees mirror the real layout (core/, explore/, kernels/) so the
path-scoped rules apply to them unchanged.  The self-scan test at the
bottom is the contract this PR adds: ``src/repro`` stays clean modulo
the checked-in baseline, forever.
"""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import pytest

from repro.analysis import Baseline, scan_paths

REPO = Path(__file__).resolve().parents[1]
BASELINE = REPO / "analysis_baseline.json"


def run_tree(tmp_path, files, tests=None, **kw):
  """Scan a {relpath: source} fixture tree (tests= adds a tests dir).

  Each call gets a fresh root so repeated calls in one test don't see
  each other's fixture files.
  """
  root = Path(tempfile.mkdtemp(dir=tmp_path)) / "pkg"
  for rel, src in files.items():
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(src)
  if tests is not None:
    tdir = root.parent / "tests"
    tdir.mkdir(exist_ok=True)
    for name, src in tests.items():
      (tdir / name).write_text(src)
  else:
    tdir = root.parent / "no_tests_dir"  # nonexistent: disables CON002
  return scan_paths([root], tests_dir=tdir, **kw)


def codes(report):
  return sorted(f.rule for f in report.findings)


# ---------------------------------------------------------------------------
# determinism pack
# ---------------------------------------------------------------------------

class TestDeterminism:

  def test_global_numpy_random_flagged(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py":
                              "import numpy as np\nv = np.random.rand(3)\n"})
    assert codes(rep) == ["DET001"]

  def test_seeded_randomstate_clean(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py":
                              "import numpy as np\n"
                              "rng = np.random.RandomState(0)\n"
                              "v = rng.rand(3)\n"})
    assert codes(rep) == []

  def test_unseeded_factory_flagged(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py":
                              "import numpy as np\n"
                              "rng = np.random.default_rng()\n"})
    assert codes(rep) == ["DET002"]

  def test_wall_clock_scoped(self, tmp_path):
    src = "import time\nt = time.time()\n"
    assert codes(run_tree(tmp_path, {"core/x.py": src})) == ["DET003"]
    # out of the determinism dirs: allowed
    assert codes(run_tree(tmp_path, {"launch/x.py": src})) == []
    # monotonic benchmarking clocks are allowed everywhere
    assert codes(run_tree(tmp_path, {
        "core/y.py": "import time\nt = time.perf_counter()\n"})) == []

  def test_set_iteration_flagged(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/x.py":
                              "out = [y for y in {1, 2, 3}]\n"})
    assert codes(rep) == ["DET004"]
    assert codes(run_tree(tmp_path, {
        "explore/y.py": "out = [y for y in sorted({1, 2, 3})]\n"})) == []

  def test_adhoc_seed_arithmetic_flagged(self, tmp_path):
    rep = run_tree(tmp_path, {"data/x.py":
                              "import numpy as np\n"
                              "def f(seed, i):\n"
                              "  return np.random.RandomState(seed * 7 + i)\n"})
    assert codes(rep) == ["DET005"]

  def test_derive_seed_clean(self, tmp_path):
    rep = run_tree(tmp_path, {"data/x.py":
                              "import numpy as np\n"
                              "from repro.core.seeding import derive_seed\n"
                              "def f(seed, i):\n"
                              "  return np.random.RandomState("
                              "derive_seed('x', seed, i))\n"})
    assert codes(rep) == []


# ---------------------------------------------------------------------------
# exactness pack
# ---------------------------------------------------------------------------

class TestExactness:

  def test_float32_in_parity_module(self, tmp_path):
    src = "import numpy as np\ndef f(x):\n  return x.astype(np.float32)\n"
    assert codes(run_tree(tmp_path, {"core/oracle.py": src})) == ["EXA001"]
    # same code outside the parity-critical set: fine
    assert codes(run_tree(tmp_path, {"core/other.py": src})) == []

  def test_divergent_transcendental_in_array_context(self, tmp_path):
    assert codes(run_tree(tmp_path, {
        "core/oracle.py": "def f(c, xp):\n  return xp.log2(c)\n"
    })) == ["EXA002"]
    # sqrt is IEEE-exact; host np.log2 is the libm reference itself
    assert codes(run_tree(tmp_path, {
        "core/oracle.py": "import numpy as np\n"
                          "def f(c, xp):\n  return xp.sqrt(c)\n"
                          "def g(x):\n  return np.log2(x)\n"})) == []

  def test_fractional_pow_in_array_context(self, tmp_path):
    assert codes(run_tree(tmp_path, {
        "explore/device.py": "def f(c, xp):\n  return c ** 0.7\n"
    })) == ["EXA002"]
    assert codes(run_tree(tmp_path, {
        "explore/device.py": "def f(c, xp):\n  return c ** 2\n"})) == []

  def test_reassociating_reduction(self, tmp_path):
    assert codes(run_tree(tmp_path, {
        "core/dataflow.py": "def f(v, xp):\n  return xp.dot(v, v)\n"
    })) == ["EXA003"]
    assert codes(run_tree(tmp_path, {
        "core/dataflow.py": "def f(v, xp):\n  return v.sum()\n"
    })) == ["EXA003"]

  def test_kernel_divergent_op_needs_ref(self, tmp_path):
    kern = "import jax.numpy as jnp\ndef k(x):\n  return jnp.exp(x)\n"
    rep = run_tree(tmp_path, {"kernels/foo/kernel.py": kern},
                   rules=["EXA004"])
    assert codes(rep) == ["EXA004"]
    rep = run_tree(tmp_path, {"kernels/foo/kernel.py": kern,
                              "kernels/foo/ref.py": "def k_ref(x): ...\n"},
                   rules=["EXA004"])
    assert codes(rep) == []


# ---------------------------------------------------------------------------
# jit-purity pack
# ---------------------------------------------------------------------------

class TestJitPurity:

  def test_print_in_decorated_jit(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py":
                              "import jax\n"
                              "@jax.jit\n"
                              "def f(x):\n  print(x)\n  return x\n"})
    assert codes(rep) == ["JIT001"]

  def test_global_mutation_in_jit(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py":
                              "import jax, functools\n"
                              "S = 0\n"
                              "@functools.partial(jax.jit)\n"
                              "def f(x):\n"
                              "  global S\n  S = 1\n  return x\n"})
    assert codes(rep) == ["JIT002"]

  def test_host_numpy_propagates_through_calls(self, tmp_path):
    # f is jitted at a call site; f calls g by name; g uses host numpy
    rep = run_tree(tmp_path, {"core/x.py":
                              "import jax\nimport numpy as np\n"
                              "def g(x):\n  return np.zeros_like(x)\n"
                              "def f(x):\n  return g(x)\n"
                              "run = jax.jit(f)\n"})
    assert codes(rep) == ["JIT003"]

  def test_item_coercion_in_pallas_kernel(self, tmp_path):
    rep = run_tree(tmp_path, {"kernels/foo/kernel.py":
                              "from jax.experimental import pallas as pl\n"
                              "def kern(x_ref, o_ref):\n"
                              "  o_ref[...] = x_ref[...].item()\n"
                              "def call(x):\n"
                              "  return pl.pallas_call(kern)(x)\n"},
                   rules=["JIT004"])
    assert codes(rep) == ["JIT004"]

  def test_builder_returned_callables_are_roots(self, tmp_path):
    # explore/device.py's make_eval_fn is a configured jit-root builder:
    # its returned nested function is traced even with no local jit call
    rep = run_tree(tmp_path, {"explore/device.py":
                              "def make_eval_fn(layers, plan):\n"
                              "  def run(inputs):\n"
                              "    print('tracing')\n"
                              "    return inputs\n"
                              "  return run\n"},
                   rules=["JIT001"])
    assert codes(rep) == ["JIT001"]

  def test_host_side_code_clean(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py":
                              "import numpy as np\n"
                              "def f(x):\n"
                              "  print(x)\n  return np.zeros(3)\n"})
    assert codes(rep) == []


# ---------------------------------------------------------------------------
# contract pack
# ---------------------------------------------------------------------------

class TestContract:

  def test_kernel_missing_siblings(self, tmp_path):
    rep = run_tree(tmp_path, {"kernels/foo/kernel.py": "def k(): ...\n"},
                   rules=["CON001"])
    assert codes(rep) == ["CON001"]
    rep = run_tree(tmp_path, {"kernels/foo/kernel.py": "def k(): ...\n",
                              "kernels/foo/ref.py": "",
                              "kernels/foo/ops.py": ""},
                   rules=["CON001"])
    assert codes(rep) == []

  def test_kernel_needs_interpret_test(self, tmp_path):
    files = {"kernels/foo/kernel.py": "def k(): ...\n",
             "kernels/foo/ref.py": "", "kernels/foo/ops.py": ""}
    rep = run_tree(tmp_path, files, tests={"test_other.py": "# nothing\n"},
                   rules=["CON002"])
    assert codes(rep) == ["CON002"]
    rep = run_tree(tmp_path, files, tests={
        "test_k.py": "from pkg.kernels.foo import ops\n"
                     "def test_k():\n"
                     "  assert ops.k(interpret=True) is not None\n"},
                   rules=["CON002"])
    assert codes(rep) == []

  def test_reducer_missing_surface(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/streaming.py":
                              "class Reducer:\n  ...\n"
                              "class Broken(Reducer):\n"
                              "  def fold(self, frame, idx): ...\n"})
    assert codes(rep) == ["CON003"]

  def test_device_spec_unknown_type(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/streaming.py":
                              "class Reducer:\n  ...\n"
                              "class Bad(Reducer):\n"
                              "  def fold(self, frame, idx): ...\n"
                              "  def result(self): ...\n"
                              "  def device_spec(self):\n"
                              "    return {'k': 3}\n"})
    assert codes(rep) == ["CON004"]

  def test_device_spec_known_or_none_clean(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/streaming.py":
                              "class Reducer:\n  ...\n"
                              "class Good(Reducer):\n"
                              "  def fold(self, frame, idx): ...\n"
                              "  def result(self): ...\n"
                              "  def device_spec(self):\n"
                              "    from repro.explore.device import TopKSpec\n"
                              "    return TopKSpec('perf', 5, True)\n"
                              "class OptOut(Reducer):\n"
                              "  def fold(self, frame, idx): ...\n"
                              "  def result(self): ...\n"
                              "  def device_spec(self):\n"
                              "    return None\n"})
    assert codes(rep) == []

  def test_search_seed_routing_flagged(self, tmp_path):
    # raw seed into a sink inside the search module: CON005 (stricter
    # than DET005 — even a clean variable holding a derived seed fails)
    rep = run_tree(tmp_path, {"explore/search.py":
                              "import numpy as np\n"
                              "def gen(seed):\n"
                              "  return np.random.RandomState(seed)\n"},
                   rules=["CON005"])
    assert codes(rep) == ["CON005"]
    rep = run_tree(tmp_path, {"explore/search.py":
                              "import numpy as np\n"
                              "from repro.core.seeding import derive_seed\n"
                              "def gen(seed, g):\n"
                              "  s = derive_seed('search-gen', seed, g)\n"
                              "  return np.random.RandomState(s)\n"},
                   rules=["CON005"])
    assert codes(rep) == ["CON005"]

  def test_search_seed_routing_direct_derivation_clean(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/search.py":
                              "import numpy as np\n"
                              "from repro.core.seeding import derive_seed\n"
                              "def gen(seed, g):\n"
                              "  return np.random.RandomState(\n"
                              "      derive_seed('search-gen', seed, g))\n"},
                   rules=["CON005"])
    assert codes(rep) == []

  def test_search_seed_routing_scoped_to_search_module(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/other.py":
                              "import numpy as np\n"
                              "def gen(seed):\n"
                              "  return np.random.RandomState(seed)\n"},
                   rules=["CON005"])
    assert codes(rep) == []


# ---------------------------------------------------------------------------
# robustness pack
# ---------------------------------------------------------------------------

class TestRobustness:

  def test_bare_except_flagged(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/eng.py":
                              "def f():\n"
                              "  try:\n"
                              "    work()\n"
                              "  except:\n"
                              "    cleanup()\n"},
                   rules=["ROB001"])
    assert codes(rep) == ["ROB001"]

  def test_swallowed_exception_flagged(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/eng.py":
                              "def f():\n"
                              "  try:\n"
                              "    work()\n"
                              "  except ValueError:\n"
                              "    pass\n"},
                   rules=["ROB001"])
    assert codes(rep) == ["ROB001"]

  def test_handler_that_acts_clean(self, tmp_path):
    # re-raising, returning a sentinel, or recording the failure all
    # keep the error visible — none of these are swallowing
    rep = run_tree(tmp_path, {"explore/eng.py":
                              "def f():\n"
                              "  try:\n"
                              "    return work()\n"
                              "  except ValueError:\n"
                              "    return None\n"
                              "  except RuntimeError as e:\n"
                              "    raise KeyError(str(e)) from e\n"},
                   rules=["ROB001"])
    assert codes(rep) == []

  def test_scoped_to_explore(self, tmp_path):
    # train/ and launch/ are outside the fault-tolerance contract
    rep = run_tree(tmp_path, {"train/loop.py":
                              "def f():\n"
                              "  try:\n"
                              "    work()\n"
                              "  except:\n"
                              "    pass\n"},
                   rules=["ROB001"])
    assert codes(rep) == []

  def test_unbounded_join_flagged(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/svc.py":
                              "def f(t, ev, cond):\n"
                              "  t.join()\n"
                              "  ev.wait()\n"
                              "  cond.wait()\n"},
                   rules=["ROB002"])
    assert codes(rep) == ["ROB002"] * 3

  def test_bounded_join_clean(self, tmp_path):
    # timeouts (positional or keyword) and string joins are fine
    rep = run_tree(tmp_path, {"explore/svc.py":
                              "def f(t, ev, parts):\n"
                              "  t.join(5.0)\n"
                              "  ev.wait(timeout=0.05)\n"
                              "  return ','.join(parts)\n"},
                   rules=["ROB002"])
    assert codes(rep) == []

  def test_futures_wait_without_timeout_flagged(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/pool.py":
                              "from concurrent.futures import wait\n"
                              "def f(pending):\n"
                              "  wait(pending)\n"},
                   rules=["ROB002"])
    assert codes(rep) == ["ROB002"]

  def test_futures_wait_with_timeout_clean(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/pool.py":
                              "from concurrent.futures import wait\n"
                              "def f(pending):\n"
                              "  wait(pending, timeout=60.0)\n"
                              "  wait(pending, 60.0)\n"},
                   rules=["ROB002"])
    assert codes(rep) == []

  def test_join_scoped_to_explore(self, tmp_path):
    rep = run_tree(tmp_path, {"serve/loop.py":
                              "def f(t):\n"
                              "  t.join()\n"},
                   rules=["ROB002"])
    assert codes(rep) == []

  def test_direct_device_enumeration_flagged_tree_wide(self, tmp_path):
    # ROB003 is NOT scoped to explore/ — launch/serve placement code
    # bypassing the fleet health registry is exactly the bug
    rep = run_tree(tmp_path, {"launch/mesh.py":
                              "import jax\n"
                              "def mesh():\n"
                              "  return jax.devices()\n",
                              "serve/place.py":
                              "import jax\n"
                              "def place():\n"
                              "  return jax.local_devices()[0]\n"},
                   rules=["ROB003"])
    assert codes(rep) == ["ROB003"] * 2

  def test_fleet_module_is_the_sanctioned_call_site(self, tmp_path):
    rep = run_tree(tmp_path, {"explore/fleet.py":
                              "import jax\n"
                              "def visible_devices():\n"
                              "  return tuple(jax.devices())\n"},
                   rules=["ROB003"])
    assert codes(rep) == []

  def test_fleet_routed_enumeration_clean(self, tmp_path):
    # going through the fleet layer (or unrelated .devices() methods on
    # non-jax objects) is fine
    rep = run_tree(tmp_path, {"launch/mesh.py":
                              "from repro.explore.fleet import "
                              "visible_devices\n"
                              "def mesh(registry):\n"
                              "  return visible_devices() + "
                              "registry.devices()\n"},
                   rules=["ROB003"])
    assert codes(rep) == []


# ---------------------------------------------------------------------------
# engine mechanics: suppressions, baseline, fingerprints, parse errors
# ---------------------------------------------------------------------------

BAD_DET = ("import numpy as np\n"
           "v = np.random.rand(3)\n")


class TestEngine:

  def test_inline_suppression_same_line(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py":
                              "import numpy as np\n"
                              "v = np.random.rand(3)  "
                              "# repro: ignore[DET001]\n"})
    assert codes(rep) == [] and rep.inline_suppressed == 1

  def test_inline_suppression_previous_line(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py":
                              "import numpy as np\n"
                              "# repro: ignore[DET001]\n"
                              "v = np.random.rand(3)\n"})
    assert codes(rep) == [] and rep.inline_suppressed == 1

  def test_wrong_id_does_not_suppress(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py":
                              "import numpy as np\n"
                              "v = np.random.rand(3)  "
                              "# repro: ignore[EXA001]\n"})
    assert codes(rep) == ["DET001"]

  def test_baseline_round_trip(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py": BAD_DET})
    assert len(rep.new) == 1
    base = Baseline.from_findings(rep.findings, justification="legacy")
    path = tmp_path / "base.json"
    base.save(path)
    rep2 = run_tree(tmp_path, {"core/x.py": BAD_DET},
                    baseline=Baseline.load(path))
    assert rep2.new == [] and len(rep2.baselined) == 1 and rep2.ok

  def test_baseline_goes_stale_when_line_changes(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py": BAD_DET})
    base = Baseline.from_findings(rep.findings)
    rep2 = run_tree(tmp_path, {"core/x.py":
                               "import numpy as np\n"
                               "v = np.random.rand(4)\n"},  # text changed
                    baseline=base)
    assert len(rep2.new) == 1 and len(rep2.stale_baseline) == 1

  def test_fingerprint_stable_under_line_shift(self, tmp_path):
    rep1 = run_tree(tmp_path, {"core/x.py": BAD_DET})
    rep2 = run_tree(tmp_path, {"core/y.py":
                               "# a new leading comment\n\n" + BAD_DET})
    # different file name => different fingerprint, so compare via text
    f1, f2 = rep1.findings[0], rep2.findings[0]
    assert f1.line != f2.line
    base = Baseline.from_findings([f2])
    rep3 = run_tree(tmp_path, {"core/y.py":
                               "# yet another comment\n\n\n" + BAD_DET},
                    baseline=base)
    assert rep3.new == []  # moved again, fingerprint still matches

  def test_parse_error_is_a_finding(self, tmp_path):
    rep = run_tree(tmp_path, {"core/x.py": "def broken(:\n"})
    assert codes(rep) == ["ANA001"]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _cli(args, cwd=REPO):
  env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
  return subprocess.run([sys.executable, "-m", "repro.analysis"] + args,
                        capture_output=True, text=True, env=env, cwd=cwd)


class TestCli:

  def test_bad_tree_fails_json(self, tmp_path):
    bad = tmp_path / "core"
    bad.mkdir()
    (bad / "x.py").write_text(BAD_DET)
    r = _cli([str(tmp_path), "--baseline", "none", "--format", "json",
              "--tests-dir", "none"])
    assert r.returncode == 1
    data = json.loads(r.stdout)
    assert data["counts"]["new"] == 1 and not data["ok"]

  def test_sarif_output(self, tmp_path):
    bad = tmp_path / "core"
    bad.mkdir()
    (bad / "x.py").write_text(BAD_DET)
    out = tmp_path / "out.sarif"
    r = _cli([str(tmp_path), "--baseline", "none", "--format", "sarif",
              "--output", str(out), "--tests-dir", "none"])
    assert r.returncode == 1
    doc = json.loads(out.read_text())
    assert doc["version"] == "2.1.0"
    assert doc["runs"][0]["results"][0]["ruleId"] == "DET001"
    assert any(rule["id"] == "DET001"
               for rule in doc["runs"][0]["tool"]["driver"]["rules"])

  def test_list_rules(self):
    r = _cli(["--list-rules"])
    assert r.returncode == 0
    for rid in ("DET001", "EXA002", "JIT003", "CON001"):
      assert rid in r.stdout


# ---------------------------------------------------------------------------
# the contract itself: src/repro is clean modulo the checked-in baseline
# ---------------------------------------------------------------------------

class TestSelfScan:

  def test_src_repro_clean_modulo_baseline(self):
    baseline = Baseline.load(BASELINE)
    assert len(baseline.entries) <= 5, \
        "baseline must stay near-empty; fix findings instead of banking them"
    for e in baseline.entries:
      assert e.get("justification", "").strip() not in ("", "TODO: justify or fix"), \
          f"baseline entry {e['fingerprint']} has no justification"
    rep = scan_paths([REPO / "src" / "repro"], tests_dir=REPO / "tests",
                     baseline=baseline)
    assert rep.new == [], "\n".join(
        f"{f.location()} {f.rule} {f.message}" for f in rep.new)
    assert rep.stale_baseline == [], \
        "baseline entries match nothing — prune them"

  def test_cli_self_scan_exits_zero(self):
    r = _cli(["src/repro", "--baseline", "analysis_baseline.json",
              "--strict-baseline"])
    assert r.returncode == 0, r.stdout + r.stderr
