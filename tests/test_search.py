"""Guided-search harness tests (repro.explore.search).

Front-quality property tests run on analytic benchmark problems with
known Pareto fronts (ZDT1/ZDT2 in 2-D, DTLZ2 in 3-D) mapped onto a
DesignSpace: every axis becomes a decision variable x_i = value/32 in
[0, 1].  The three headline properties:

  * the optimizer's front dominates random sampling at equal evaluation
    budget (hypervolume, shared reference point);
  * same-seed reruns are bit-identical (front columns byte-for-byte);
  * re-folding the recorded generations through a fresh
    ParetoAccumulator in any shuffled order reproduces the one-shot
    front exactly (the streaming chunk-order-invariance contract).
"""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core.workloads import get_network
from repro.explore import (DesignSpace, ExplorationSession,
                           ParetoAccumulator, VectorOracleBackend,
                           crowding_distance, guided_search, hypervolume,
                           nondominated_ranks, objective_matrix,
                           pareto_mask)
from repro.explore.frame import ResultFrame
from repro.explore.streaming import Reducer

INTS = ("pe_rows", "pe_cols", "sp_if", "sp_fw", "sp_ps", "gbuf_kb")
GRID = 33  # values 0..32 -> x = value/32 covers [0, 1] incl. exact 0.5


def unit_space() -> DesignSpace:
  """Every axis an evenly-spaced 33-point decision variable in [0, 1]."""
  axes = {name: tuple(range(GRID)) for name in INTS}
  axes["bandwidth_gbps"] = tuple(np.linspace(0.0, 1.0, GRID))
  return DesignSpace(pe_types=("INT8",), axes=axes)


def decision_vars(table) -> np.ndarray:
  """(n, 7) matrix of x_i in [0, 1] from a unit_space table."""
  cols = [np.asarray(getattr(table, n), np.float64) / (GRID - 1)
          for n in INTS]
  return np.stack(cols + [table.bandwidth_gbps], axis=1)


def _frame(objs, table) -> ResultFrame:
  """Pack up-to-3 minimized objectives into the frame's base columns."""
  pad = [np.ones(len(table))] * (3 - len(objs))
  return ResultFrame(*(list(objs) + pad), table.pe_type_strings(),
                     table=table)


def zdt1(table, idx, arch):
  x = decision_vars(table)
  f1 = x[:, 0]
  g = 1.0 + 9.0 * x[:, 1:].mean(axis=1)
  f2 = g * (1.0 - np.sqrt(f1 / g))
  return _frame((f1, f2), table), idx


def zdt2(table, idx, arch):
  x = decision_vars(table)
  f1 = x[:, 0]
  g = 1.0 + 9.0 * x[:, 1:].mean(axis=1)
  f2 = g * (1.0 - (f1 / g) ** 2)  # non-convex true front
  return _frame((f1, f2), table), idx


def dtlz2(table, idx, arch):
  x = decision_vars(table)
  g = ((x[:, 2:] - 0.5) ** 2).sum(axis=1)  # 0 exactly on the true front
  c1, s1 = np.cos(np.pi * x[:, 0] / 2), np.sin(np.pi * x[:, 0] / 2)
  c2, s2 = np.cos(np.pi * x[:, 1] / 2), np.sin(np.pi * x[:, 1] / 2)
  return _frame(((1 + g) * c1 * c2, (1 + g) * c1 * s2, (1 + g) * s1),
                table), idx


OBJ2 = ("latency_s", "power_mw")
OBJ3 = ("latency_s", "power_mw", "area_mm2")


def front_hv(res, cols, ref) -> float:
  f = res["pareto"]
  return hypervolume(
      np.stack([f.column(c) for c in cols], axis=1), ref)


def random_front_hv(space, evaluate, budget, seed, cols, ref) -> float:
  tbl = space.sample_type_table("INT8", budget, seed=seed)
  frame, _ = evaluate(tbl, np.arange(len(tbl)), None)
  obj = np.stack([frame.column(c) for c in cols], axis=1)
  return hypervolume(obj[pareto_mask(obj)], ref)


class _Recorder(Reducer):
  """Captures every folded (frame, indices) generation for re-folding."""

  def __init__(self):
    self.chunks = []

  def fold(self, frame, indices):
    self.chunks.append((frame, np.asarray(indices, np.int64).copy()))

  def result(self):
    return self.chunks


# ---------------------------------------------------------------------------
# hypervolume: known analytic values + invariances
# ---------------------------------------------------------------------------

class TestHypervolume:

  def test_known_2d_values(self):
    assert hypervolume([[0.0, 0.0]], (1.0, 1.0)) == pytest.approx(1.0)
    # two staircase points: [0,1]x[.5,1] + [.5,1]x[0,1] minus overlap
    assert hypervolume([[0.0, 0.5], [0.5, 0.0]],
                       (1.0, 1.0)) == pytest.approx(0.75)
    # a dominated point adds nothing
    assert hypervolume([[0.0, 0.5], [0.5, 0.0], [0.6, 0.6]],
                       (1.0, 1.0)) == pytest.approx(0.75)
    # points at/outside the reference contribute nothing
    assert hypervolume([[1.0, 0.0], [2.0, -1.0]], (1.0, 1.0)) == 0.0
    assert hypervolume(np.zeros((0, 2)), (1.0, 1.0)) == 0.0

  def test_known_3d_values(self):
    assert hypervolume([[0.0, 0.0, 0.0]],
                       (1.0, 1.0, 1.0)) == pytest.approx(1.0)
    # two unit sub-cubes overlapping in a quarter-cube
    pts = [[0.0, 0.0, 0.5], [0.5, 0.0, 0.0]]
    assert hypervolume(pts, (1.0, 1.0, 1.0)) == pytest.approx(0.75)
    # duplicated points count once
    assert hypervolume(pts + pts, (1.0, 1.0, 1.0)) == pytest.approx(0.75)

  def test_matches_monte_carlo_3d(self):
    rng = np.random.RandomState(5)
    pts = rng.rand(24, 3)
    ref = (1.0, 1.0, 1.0)
    samples = rng.rand(200_000, 3)
    dominated = np.zeros(len(samples), np.bool_)
    for p in pts:
      dominated |= np.all(samples >= p, axis=1)
    assert hypervolume(pts, ref) == pytest.approx(
        dominated.mean(), abs=5e-3)

  def test_row_permutation_invariant(self):
    rng = np.random.RandomState(11)
    pts = rng.rand(40, 3)
    ref = (1.5, 1.5, 1.5)
    base = hypervolume(pts, ref)
    for seed in range(3):
      perm = np.random.RandomState(seed).permutation(len(pts))
      assert hypervolume(pts[perm], ref) == base

  @settings(max_examples=20, deadline=None, derandomize=True)
  @given(st.integers(0, 2 ** 31 - 1), st.integers(2, 3))
  def test_dominated_points_never_change_hv(self, seed, dim):
    rng = np.random.RandomState(seed % 2 ** 31)
    pts = rng.rand(12, dim)
    ref = np.full(dim, 1.25)
    base = hypervolume(pts, ref)
    # any point >= an existing point is dominated (or equal): no change
    extra = np.minimum(pts[rng.randint(len(pts))] + rng.rand(dim), 1.2)
    assert hypervolume(np.vstack([pts, extra]), ref) == pytest.approx(
        base, rel=1e-12)

  def test_shape_validation(self):
    with pytest.raises(ValueError):
      hypervolume(np.zeros(3), (1.0,))
    with pytest.raises(ValueError):
      hypervolume(np.zeros((2, 3)), (1.0, 1.0))


# ---------------------------------------------------------------------------
# NSGA-II building blocks
# ---------------------------------------------------------------------------

class TestSelectionKernels:

  def test_nondominated_ranks_layered(self):
    # three nested diagonal fronts
    obj = np.array([[0.0, 2.0], [1.0, 1.0], [2.0, 0.0],
                    [1.0, 3.0], [2.0, 2.0],
                    [3.0, 3.0]])
    assert nondominated_ranks(obj).tolist() == [0, 0, 0, 1, 1, 2]

  def test_ranks_cover_every_row(self):
    rng = np.random.RandomState(3)
    obj = rng.rand(200, 3)
    ranks = nondominated_ranks(obj)
    assert ranks.min() == 0
    for r in range(int(ranks.max()) + 1):
      # each layer is itself non-dominated
      layer = obj[ranks == r]
      assert pareto_mask(layer).all()

  def test_crowding_boundaries_infinite(self):
    obj = np.array([[0.0, 4.0], [1.0, 3.0], [2.0, 2.0], [3.0, 1.0],
                    [4.0, 0.0]])
    crowd = crowding_distance(obj, np.zeros(5, np.int64))
    assert np.isinf(crowd[0]) and np.isinf(crowd[4])
    assert np.all(np.isfinite(crowd[1:4]))
    # evenly spaced interior points have equal crowding
    assert crowd[1] == pytest.approx(crowd[2]) == pytest.approx(crowd[3])

  def test_objective_matrix_sign_convention(self):
    frame = ResultFrame(np.array([2.0, 4.0]), np.array([10.0, 20.0]),
                        np.array([1.0, 1.0]), np.array(["INT8", "INT8"]))
    m = objective_matrix(frame, ("perf", "latency_s"))
    assert np.array_equal(m[:, 0], -frame.column("perf"))  # maximized
    assert np.array_equal(m[:, 1], frame.column("latency_s"))


# ---------------------------------------------------------------------------
# front quality: optimizer vs random at equal budget
# ---------------------------------------------------------------------------

class TestFrontQuality:

  @pytest.mark.parametrize("problem", [zdt1, zdt2], ids=["zdt1", "zdt2"])
  def test_beats_random_2d(self, problem):
    space = unit_space()
    ref = (1.1, 11.0)
    res = guided_search(space, problem, OBJ2, population=20,
                        generations=10, seed=3)
    hv_opt = front_hv(res, OBJ2, ref)
    hv_rand = random_front_hv(space, problem, res.n_rows, 3, OBJ2, ref)
    assert hv_opt > hv_rand

  def test_beats_random_3d(self):
    space = unit_space()
    ref = (2.5, 2.5, 2.5)
    res = guided_search(space, dtlz2, OBJ3, population=24,
                        generations=10, seed=5)
    hv_opt = front_hv(res, OBJ3, ref)
    hv_rand = random_front_hv(space, dtlz2, res.n_rows, 5, OBJ3, ref)
    assert hv_opt > hv_rand
    # the optimizer's front sits near the g == 0 sphere: |f| close to 1
    f = res["pareto"]
    norms = np.sqrt(sum(f.column(c) ** 2 for c in OBJ3))
    assert norms.mean() < 1.25  # random fronts average well above this

  @settings(max_examples=5, deadline=None, derandomize=True)
  @given(st.integers(0, 2 ** 31 - 1))
  def test_beats_random_any_seed(self, seed):
    space = unit_space()
    ref = (1.1, 11.0)
    res = guided_search(space, zdt1, OBJ2, population=20,
                        generations=10, seed=seed)
    hv_opt = front_hv(res, OBJ2, ref)
    hv_rand = random_front_hv(space, zdt1, res.n_rows, seed, OBJ2, ref)
    assert hv_opt > hv_rand

  def test_surrogate_mode_beats_random(self):
    space = unit_space()
    ref = (1.1, 11.0)
    res = guided_search(space, zdt1, OBJ2, population=20,
                        generations=10, seed=3, surrogate=True)
    assert res.meta["surrogate"] == 1.0
    hv_opt = front_hv(res, OBJ2, ref)
    hv_rand = random_front_hv(space, zdt1, res.n_rows, 3, OBJ2, ref)
    assert hv_opt > hv_rand

  def test_front_approaches_true_zdt1_front(self):
    # true front: f2 = 1 - sqrt(f1); every optimizer front point should
    # end well below the g ~= 5.5 band random sampling lives in
    res = guided_search(unit_space(), zdt1, OBJ2, population=24,
                        generations=16, seed=7)
    f = res["pareto"]
    excess = f.column("power_mw") - (1.0 - np.sqrt(f.column("latency_s")))
    assert np.all(excess >= -1e-12)  # never below the analytic front
    assert excess.mean() < 1.0


# ---------------------------------------------------------------------------
# determinism + streaming-fold exactness
# ---------------------------------------------------------------------------

class TestDeterminism:

  @pytest.mark.parametrize("surrogate", [False, True],
                           ids=["evolutionary", "surrogate"])
  def test_same_seed_bit_identical(self, surrogate):
    space = unit_space()
    runs = [guided_search(space, zdt1, OBJ2, population=16, generations=6,
                          seed=11, surrogate=surrogate) for _ in range(2)]
    a, b = (r["pareto"] for r in runs)
    assert len(a) == len(b)
    for col in OBJ2:
      assert np.array_equal(a.column(col), b.column(col))
    assert np.array_equal(a.table.pe_rows, b.table.pe_rows)
    assert runs[0].n_rows == runs[1].n_rows
    assert runs[0].meta["hypervolume"] == runs[1].meta["hypervolume"]

  def test_different_seeds_differ(self):
    space = unit_space()
    a = guided_search(space, zdt1, OBJ2, population=16, generations=6,
                      seed=1)
    b = guided_search(space, zdt1, OBJ2, population=16, generations=6,
                      seed=2)
    assert not np.array_equal(a["pareto"].column("latency_s"),
                              b["pareto"].column("latency_s"))

  @pytest.mark.parametrize("shuffle_seed", [0, 1, 2])
  def test_shuffled_generation_folds_reproduce_front(self, shuffle_seed):
    space = unit_space()
    res = guided_search(
        space, zdt1, OBJ2, population=16, generations=8, seed=4,
        reducers={"pareto": ParetoAccumulator(OBJ2), "rec": _Recorder()})
    one_shot = res["pareto"]
    chunks = list(res["rec"])
    assert len(chunks) == int(res.meta["generations"])
    order = np.random.RandomState(shuffle_seed).permutation(len(chunks))
    acc = ParetoAccumulator(OBJ2)
    for i in order:
      acc.fold(*chunks[i])
    refolded = acc.result()
    assert len(refolded) == len(one_shot)
    for col in OBJ2:
      assert np.array_equal(refolded.column(col), one_shot.column(col))
    for knob in ("pe_rows", "bandwidth_gbps"):
      assert np.array_equal(getattr(refolded.table, knob),
                            getattr(one_shot.table, knob))

  def test_never_reevaluates_a_design_point(self):
    res = guided_search(unit_space(), zdt1, OBJ2, population=12,
                        generations=8, seed=9,
                        reducers={"pareto": ParetoAccumulator(OBJ2),
                                  "rec": _Recorder()})
    keys = [k for frame, _ in res["rec"] for k in frame.table.row_keys()]
    assert len(keys) == res.n_rows
    assert len(set(keys)) == len(keys)

  def test_exhausted_space_stops_early(self):
    # 4-point space: one live axis, everything else pinned
    axes = {name: (1,) for name in INTS}
    axes["pe_rows"] = (1, 2, 3, 4)
    axes["bandwidth_gbps"] = (1.0,)
    space = DesignSpace(pe_types=("INT8",), axes=axes)
    res = guided_search(space, zdt1, OBJ2, population=2, generations=10,
                        seed=0)
    assert res.n_rows <= 4
    assert res.meta["generations"] < 10

  def test_constraints_respected(self):
    from repro.explore import vector_constraint
    space = unit_space()
    space = DesignSpace(
        pe_types=("INT8",),
        axes={a.name: a.values for a in space.axes},
        constraints=(vector_constraint(lambda c: c.pe_rows <= 16,
                                       lambda t: t.pe_rows <= 16),))
    res = guided_search(space, zdt1, OBJ2, population=16, generations=6,
                        seed=2, reducers={"rec": _Recorder()})
    for frame, _ in res["rec"]:
      assert np.all(frame.table.pe_rows <= 16)

  def test_parameter_validation(self):
    space = unit_space()
    with pytest.raises(ValueError):
      guided_search(space, zdt1, (), population=8, generations=2)
    with pytest.raises(ValueError):
      guided_search(space, zdt1, OBJ2, population=1)
    with pytest.raises(ValueError):
      guided_search(space, zdt1, OBJ2, generations=0)
    with pytest.raises(ValueError):
      guided_search(space, zdt1, OBJ2, surrogate_pool=1)
    with pytest.raises(ValueError):
      guided_search(space, zdt1, OBJ2, n_archs=0)


# ---------------------------------------------------------------------------
# session.optimize: real oracle backends
# ---------------------------------------------------------------------------

class TestSessionOptimize:

  @pytest.fixture(scope="class")
  def layers(self):
    return get_network("resnet20")[:3]

  def test_hw_search_returns_stream_result(self, layers):
    session = ExplorationSession(VectorOracleBackend())
    res = session.optimize(layers, population=8, generations=3, seed=1)
    front = res["pareto"]
    assert len(front) >= 1
    assert res.meta["evaluations"] == res.n_rows == 24
    assert res.meta["generations"] == 3
    # default objectives: the paper's (perf_per_area, energy) axes
    assert front.column("perf_per_area").shape == (len(front),)
    assert pareto_mask(objective_matrix(
        front, ("perf_per_area", "energy_mj"))).all()

  def test_hw_search_bit_identical(self, layers):
    session = ExplorationSession(VectorOracleBackend())
    a = session.optimize(layers, population=8, generations=3, seed=1)
    b = session.optimize(layers, population=8, generations=3, seed=1)
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(a["pareto"].column(col),
                            b["pareto"].column(col))

  def test_joint_search(self, layers):
    from repro.core.supernet import SEARCH_SPACE, ArchChoice
    rng = np.random.RandomState(7)
    arch_accs = []
    for i in range(5):
      arch = ArchChoice(tuple(
          (int(rng.choice(reps)), int(rng.choice(chs)))
          for reps, chs in SEARCH_SPACE))
      arch_accs.append((arch, 0.6 + 0.05 * i))
    session = ExplorationSession(VectorOracleBackend())
    res = session.optimize(arch_accs=arch_accs, population=8,
                           generations=3, seed=2, image_size=16)
    front = res["pareto"]
    assert len(front) >= 1
    assert front.arch_lookup  # archs resolvable
    aid = front.column("arch_id")
    assert np.all((aid >= 0) & (aid < len(arch_accs)))
    assert np.all(front.column("top1_err")
                  == 1.0 - np.asarray([arch_accs[int(i)][1] for i in aid]))
    # joint rerun is bit-identical too
    res2 = session.optimize(arch_accs=arch_accs, population=8,
                            generations=3, seed=2, image_size=16)
    for col in ("top1_err", "energy_mj", "area_mm2"):
      assert np.array_equal(front.column(col), res2["pareto"].column(col))

  def test_mode_validation(self, layers):
    session = ExplorationSession(VectorOracleBackend())
    with pytest.raises(ValueError, match="exactly one"):
      session.optimize()
    with pytest.raises(ValueError, match="exactly one"):
      session.optimize(layers, arch_accs=[(None, 0.5)])


# ---------------------------------------------------------------------------
# fault tolerance: generation-as-chunk kill/resume bit-identity
# ---------------------------------------------------------------------------

class TestSearchResume:
  GENS = 6

  def run(self, **kw):
    return guided_search(unit_space(), zdt1, OBJ2, population=12,
                         generations=self.GENS, seed=3, **kw)

  def test_kill_at_every_generation_resumes_bit_identically(self, tmp_path):
    from repro.explore import (ChunkError, Fault, FaultPlan,
                               ResiliencePolicy, RetryPolicy)
    ref = self.run()
    for g in range(self.GENS):
      jdir = tmp_path / f"kill-{g}"
      pol = ResiliencePolicy(
          retry=RetryPolicy(sleep=lambda s: None),
          fault_plan=FaultPlan([Fault("kill", g, "task")]))
      with pytest.raises(ChunkError) as err:
        self.run(policy=pol, resume_from=jdir)
      assert err.value.chunk_index == g
      res = self.run(resume_from=jdir)
      for col in OBJ2:
        assert np.array_equal(res["pareto"].column(col),
                              ref["pareto"].column(col)), (g, col)
      assert res.meta["n_resumed_chunks"] == float(g)
      assert res.meta["evaluations"] == ref.meta["evaluations"]

  def test_finished_run_extends_from_journal(self, tmp_path):
    # `generations` is excluded from the journal key: a finished run's
    # record seeds a longer one, which replays no evaluations
    short = guided_search(unit_space(), zdt1, OBJ2, population=12,
                          generations=3, seed=3, resume_from=tmp_path)
    longer = guided_search(unit_space(), zdt1, OBJ2, population=12,
                           generations=self.GENS, seed=3,
                           resume_from=tmp_path)
    ref = self.run()
    assert longer.meta["n_resumed_chunks"] == 3.0
    assert longer.meta["evaluations"] == ref.meta["evaluations"]
    for col in OBJ2:
      assert np.array_equal(longer["pareto"].column(col),
                            ref["pareto"].column(col)), col
    del short

  def test_unexpected_failure_wrapped_with_generation(self):
    from repro.explore import ChunkError
    calls = {"n": 0}

    def evaluate(table, idx, arch):
      if calls["n"] == 2:
        raise OSError("device fell off the bus")
      calls["n"] += 1
      return zdt1(table, idx, arch)

    with pytest.raises(ChunkError) as err:
      guided_search(unit_space(), evaluate, OBJ2, population=12,
                    generations=4, seed=3)
    assert err.value.chunk_index == 2
    assert "OSError" in str(err.value)

  def test_surrogate_resume_bit_identical(self, tmp_path):
    from repro.explore import (ChunkError, Fault, FaultPlan,
                               ResiliencePolicy, RetryPolicy)
    kw = dict(population=12, generations=self.GENS, seed=3,
              surrogate=True, surrogate_pool=2)
    ref = guided_search(unit_space(), zdt1, OBJ2, **kw)
    pol = ResiliencePolicy(
        retry=RetryPolicy(sleep=lambda s: None),
        fault_plan=FaultPlan([Fault("kill", 3, "task")]))
    with pytest.raises(ChunkError):
      guided_search(unit_space(), zdt1, OBJ2, policy=pol,
                    resume_from=tmp_path, **kw)
    res = guided_search(unit_space(), zdt1, OBJ2, resume_from=tmp_path,
                        **kw)
    for col in OBJ2:
      assert np.array_equal(res["pareto"].column(col),
                            ref["pareto"].column(col)), col
    assert res.meta["n_resumed_chunks"] == 3.0
