"""End-to-end driver: train a zoo architecture on the synthetic Markov
stream with QUIDAM QAT, checkpointing, and fault-tolerance telemetry.

Default: a reduced olmo-family model for 300 steps (CPU-friendly); pass
--arch/--steps/--pe-type to change.  Loss is asserted to decrease.

Run: PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.data.synthetic import (DataCursor, MarkovTokenStream,
                                  TokenStreamConfig, token_batches)
from repro.models.model import build_model
from repro.quant.policy import QuantPolicy
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib
from repro.train.trainer import Trainer, TrainerConfig


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="olmo-1b")
  ap.add_argument("--steps", type=int, default=300)
  ap.add_argument("--batch", type=int, default=8)
  ap.add_argument("--seq", type=int, default=128)
  ap.add_argument("--pe-type", default="FP32",
                  help="QAT policy: FP32/INT16/INT8/LightPE-1/LightPE-2")
  ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
  ap.add_argument("--full-config", action="store_true",
                  help="use the full architecture (needs accelerators)")
  args = ap.parse_args()

  cfg = get_config(args.arch)
  if not args.full_config:
    cfg = reduce_for_smoke(cfg, d_model=128, n_layers=4, d_ff=256,
                           vocab_size=2048)
  model = build_model(cfg)
  tcfg = ts_lib.TrainConfig(
      optimizer=opt_lib.AdamWConfig(lr=3e-3, warmup_steps=20,
                                    total_steps=args.steps),
      quant=QuantPolicy(pe_type=args.pe_type))
  stream = MarkovTokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                               branching=6))
  cursor = DataCursor()
  trainer = Trainer(model, tcfg,
                    TrainerConfig(total_steps=args.steps, log_every=20,
                                  ckpt_every=100, ckpt_dir=args.ckpt_dir),
                    token_batches(stream, args.batch, args.seq, cursor),
                    cursor=cursor, key=jax.random.PRNGKey(0))
  resumed = trainer.maybe_restore()
  print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
        f"pe_type={args.pe_type} resumed={resumed}")
  hist = trainer.run(args.steps - trainer.step)
  first = sum(h["loss"] for h in hist[:10]) / max(len(hist[:10]), 1)
  last = sum(h["loss"] for h in hist[-10:]) / max(len(hist[-10:]), 1)
  print(f"loss {first:.3f} -> {last:.3f} over {len(hist)} steps")
  print("straggler report:", trainer.monitor.stragglers() or "none")
  assert last < first, "loss did not decrease"


if __name__ == "__main__":
  main()
