"""Serving driver: batched requests through the continuous-batching engine
with an int8-quantized KV cache (QUIDAM's precision axis at decode time).

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.models.model import build_model
from repro.serve.engine import EngineConfig, ServeEngine


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="qwen3-0.6b")
  ap.add_argument("--requests", type=int, default=8)
  ap.add_argument("--new-tokens", type=int, default=16)
  ap.add_argument("--kv-quant", default="int8", choices=["none", "int8"])
  args = ap.parse_args()

  cfg = reduce_for_smoke(get_config(args.arch), d_model=128, n_layers=4,
                         vocab_size=2048)
  cfg = dataclasses.replace(cfg, kv_quant=args.kv_quant)
  model = build_model(cfg)
  params = model.init(jax.random.PRNGKey(0))
  engine = ServeEngine(model, params, EngineConfig(
      batch_slots=4, max_len=256, prompt_bucket=32))

  rng = np.random.RandomState(0)
  t0 = time.time()
  for i in range(args.requests):
    engine.submit(rng.randint(0, cfg.vocab_size, size=10 + i),
                  max_new_tokens=args.new_tokens)
  results = engine.run_until_drained()
  dt = time.time() - t0
  total = sum(len(v) for v in results.values())
  print(f"served {len(results)} requests / {total} tokens in {dt:.1f}s "
        f"({total / dt:.1f} tok/s on CPU) kv_quant={args.kv_quant}")
  for uid, toks in sorted(results.items())[:3]:
    print(f"  request {uid}: {toks[:8]}...")
  assert len(results) == args.requests


if __name__ == "__main__":
  main()
