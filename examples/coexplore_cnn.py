"""Full co-exploration demo (paper Sec. 4.5 / Fig. 12) via repro.explore:
train the weight-sharing VGG supernet over the Table-4 space, sample +
evaluate candidate architectures, pair with PPA-modeled hardware through
an ExplorationSession, and print the joint Pareto front.

Run: PYTHONPATH=src python examples/coexplore_cnn.py --steps 200
"""
import argparse

import numpy as np

from repro.core.supernet import Supernet, SupernetConfig, space_size
from repro.explore import ExplorationSession, PolynomialBackend


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--steps", type=int, default=200)
  ap.add_argument("--archs", type=int, default=24)
  ap.add_argument("--hw-per-type", type=int, default=12)
  args = ap.parse_args()

  print(f"search space: {space_size():,} architectures (Table 4)")
  sn = Supernet(SupernetConfig(steps=args.steps, batch=32, image_size=16))
  sn.train()
  arch_accs = sn.sample_and_evaluate(n_archs=args.archs, n_val=512)
  accs = [a for _, a in arch_accs]
  print(f"sampled {len(arch_accs)} archs; top-1 range "
        f"{min(accs):.3f}-{max(accs):.3f}")

  from repro.core.supernet import arch_to_layers
  layers = arch_to_layers(arch_accs[0][0])
  backend = PolynomialBackend.fit(degree=5, n_train=200, layers=layers)
  session = ExplorationSession(backend)
  # vectorized=True: the whole archs x HW cross product evaluates
  # array-at-a-time (JointTable + LayerStack; power/area once per HW row)
  frame = session.co_explore(arch_accs, n_hw_per_type=args.hw_per_type,
                             vectorized=True)
  front = frame.pareto(cols=("top1_err", "energy_mj"))
  print(f"\n{len(frame)} (HW, NN) pairs; energy-front breakdown:")
  for t in ("FP32", "INT16", "LightPE-2", "LightPE-1"):
    n_front = int(np.sum(front & frame.by_type(t)))
    print(f"  {t:12s}: {n_front} points on the joint Pareto front")
  lights = np.isin(frame.pe_type[front], ("LightPE-1", "LightPE-2"))
  print(f"\nLightPE share of the front: {lights.mean() * 100:.0f}% "
        "(paper: LightPEs consistently on the front)")
  front3 = frame.pareto(cols=("top1_err", "energy_mj", "area_mm2"))
  best = int(np.flatnonzero(front3)[0])
  print(f"3-objective (err, energy, area) front: {int(front3.sum())} "
        f"points; e.g. arch {frame.arch_at(best).stages} on "
        f"{frame.pe_type[best]}")


if __name__ == "__main__":
  main()
