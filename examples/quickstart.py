"""QUIDAM quickstart via the unified repro.explore API: fit PPA models
once, explore the design space, print the paper's headline comparison
(LightPE vs INT16) in under a minute.

Run: PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.workloads import get_network
from repro.explore import DesignSpace, ExplorationSession, PolynomialBackend


def main():
  layers = get_network("resnet20")
  space = DesignSpace()
  print(f"design space: {space!r}")
  print("Fitting power/area/latency polynomial models (4 PE types)...")
  backend = PolynomialBackend.fit(degree=5, n_train=200, layers=layers)
  session = ExplorationSession(backend, space)
  frame = session.explore(layers, "resnet20", n_per_type=200,
                          measure_oracle=3)
  ppa_n, en_n = frame.normalize(ref="best-int16")
  print(f"\n{len(frame)} design points (ResNet-20), normalized to the "
        "best INT16 configuration:")
  print(f"{'PE type':12s} {'best perf/area':>15s} {'best energy':>12s}")
  for t in ("FP32", "INT16", "LightPE-2", "LightPE-1"):
    m = frame.by_type(t)
    print(f"{t:12s} {ppa_n[m].max():14.2f}x {en_n[m].min():11.3f}x")
  print(f"\nmodel eval: {frame.meta['eval_us_per_design']:.0f} "
        f"us/design vs oracle "
        f"{frame.meta['oracle_seconds_per_design'] * 1e3:.1f} "
        "ms/design (vs hours for real synthesis)")
  best = frame.top_k(1, by="perf_per_area")
  print(f"best design: {best.cfgs[0]}")


if __name__ == "__main__":
  main()
