"""QUIDAM quickstart via the unified repro.explore API: fit PPA models
once, explore the design space, print the paper's headline comparison
(LightPE vs INT16), then rerun the sweep through the vectorized exact
oracle (ConfigTable + VectorOracleBackend) — all in under a minute.

Run: PYTHONPATH=src python examples/quickstart.py
Env: QUICKSTART_JIT=1        enable the approximate jax.jit device path
     QUICKSTART_CHUNK=65536  vector backend chunk size (bounded memory)
"""
import os
import time

from repro.core.workloads import get_network
from repro.explore import (DesignSpace, ExplorationSession,
                           PolynomialBackend, VectorOracleBackend)


def main():
  layers = get_network("resnet20")
  space = DesignSpace()
  print(f"design space: {space!r}")

  # --- the paper's fast path: fit-once polynomial models -------------------
  print("Fitting power/area/latency polynomial models (4 PE types)...")
  backend = PolynomialBackend.fit(degree=5, n_train=200, layers=layers)
  session = ExplorationSession(backend, space)
  frame = session.explore(layers, "resnet20", n_per_type=200,
                          measure_oracle=3)
  ppa_n, en_n = frame.normalize(ref="best-int16")
  print(f"\n{len(frame)} design points (ResNet-20), normalized to the "
        "best INT16 configuration:")
  print(f"{'PE type':12s} {'best perf/area':>15s} {'best energy':>12s}")
  for t in ("FP32", "INT16", "LightPE-2", "LightPE-1"):
    m = frame.by_type(t)
    print(f"{t:12s} {ppa_n[m].max():14.2f}x {en_n[m].min():11.3f}x")
  print(f"\nmodel eval: {frame.meta['eval_us_per_design']:.0f} "
        f"us/design vs oracle "
        f"{frame.meta['oracle_seconds_per_design'] * 1e3:.1f} "
        "ms/design (vs hours for real synthesis)")
  best = frame.top_k(1, by="perf_per_area")
  print(f"best design: {best.config_at(0)}")

  # --- the vectorized exact path: ConfigTable + VectorOracleBackend --------
  # Same oracle, array-at-a-time: a struct-of-arrays ConfigTable flows
  # through the *_batch formulas in bounded-memory chunks.  Bit-identical
  # to OracleBackend on the numpy path; QUICKSTART_JIT=1 switches the
  # per-chunk formulas to jax.jit (float32-approximate, throughput only).
  chunk = int(os.environ.get("QUICKSTART_CHUNK", "65536"))
  use_jit = os.environ.get("QUICKSTART_JIT", "0") == "1"
  n_per_type = 25_000  # 100k exact characterizations in ~a second
  vec = VectorOracleBackend(chunk_size=chunk, jit=use_jit)
  vsession = ExplorationSession(vec, space)
  t0 = time.perf_counter()
  vframe = vsession.explore(layers[:4], "resnet20-head",
                            n_per_type=n_per_type)
  dt = time.perf_counter() - t0
  print(f"\nvectorized exact oracle: {len(vframe):,} design points in "
        f"{dt:.2f}s ({len(vframe) / dt:,.0f} pts/s; chunk={chunk}, "
        f"jit={'on' if use_jit else 'off'})")
  front = vframe.pareto(cols=("perf_per_area", "energy_mj"))
  print(f"pareto front: {int(front.sum())} of {len(vframe):,} points; "
        f"best exact design: {vframe.top_k(1, by='perf_per_area').config_at(0)}")


if __name__ == "__main__":
  main()
