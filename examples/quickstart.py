"""QUIDAM quickstart: fit PPA models, explore the design space, print the
paper's headline comparison (LightPE vs INT16) in under a minute.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import dse
from repro.core.workloads import get_network


def main():
  layers = get_network("resnet20")
  print("Fitting power/area/latency polynomial models (4 PE types)...")
  explorer = dse.DesignSpaceExplorer(degree=5, n_train=200, layers=layers)
  res = explorer.explore(layers, "resnet20", n_per_type=200)
  ppa_n, en_n = dse.normalized_metrics(res.points)
  types = np.asarray([p.cfg.pe_type for p in res.points])
  print(f"\n{len(res.points)} design points (ResNet-20), normalized to the "
        "best INT16 configuration:")
  print(f"{'PE type':12s} {'best perf/area':>15s} {'best energy':>12s}")
  for t in ("FP32", "INT16", "LightPE-2", "LightPE-1"):
    m = types == t
    print(f"{t:12s} {ppa_n[m].max():14.2f}x {en_n[m].min():11.3f}x")
  print(f"\nmodel eval: {res.seconds_model / len(res.points) * 1e6:.0f} "
        f"us/design vs oracle {res.seconds_oracle_per_design * 1e3:.1f} "
        "ms/design (vs hours for real synthesis)")
  best = res.points[int(np.argmax(ppa_n))]
  print(f"best design: {best.cfg}")


if __name__ == "__main__":
  main()
