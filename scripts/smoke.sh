#!/usr/bin/env bash
# Pre-merge smoke: tier-1 tests + the paper-figure benchmark entry points.
#
# Usage:
#   scripts/smoke.sh              # full paper benchmark suite
#   SMOKE_ONLY=fig4 scripts/smoke.sh   # restrict benchmarks by substring
#
# The PPA-model fit is cached under results/cache/ppa_models.npz
# (PolynomialBackend.fit_or_load), so repeat runs never refit.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== static analysis (determinism/exactness) =="
python -m repro.analysis src/repro --baseline analysis_baseline.json \
  --strict-baseline

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== paper benchmarks =="
python -m benchmarks.run --suite paper ${SMOKE_ONLY:+--only "$SMOKE_ONLY"}

echo "== smoke OK =="
