#!/usr/bin/env python
"""Docs link checker: every relative link in the repo's markdown resolves.

Scans README.md + docs/**/*.md for ``[text](target)`` links, skipping
external (http/https/mailto) targets, and fails when a relative target
file is missing or a ``#fragment`` names a heading that does not exist
(GitHub-style slugs).  Run from anywhere: paths resolve against the repo
root.  Used by CI (.github/workflows/ci.yml) and runnable standalone:

    python scripts/check_docs_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"^```.*?^```", re.MULTILINE | re.DOTALL)
EXTERNAL = ("http://", "https://", "mailto:")


def slugify(heading: str) -> str:
  """GitHub-flavoured anchor slug: lowercase, drop punctuation (backticks
  included), spaces -> hyphens."""
  text = heading.strip().lower()
  text = re.sub(r"[`*_]", "", text)
  text = re.sub(r"[^\w\- ]", "", text)
  return text.replace(" ", "-")


def anchors_of(md: Path) -> set:
  # strip fenced code blocks first: '# comment' lines inside ``` fences
  # are not headings and must not satisfy fragment links
  text = FENCE_RE.sub("", md.read_text())
  return {slugify(h) for h in HEADING_RE.findall(text)}


def check() -> int:
  md_files = [REPO / "README.md"] + sorted((REPO / "docs").glob("**/*.md"))
  errors = []
  for md in md_files:
    if not md.exists():
      errors.append(f"{md}: expected markdown file is missing")
      continue
    for target in LINK_RE.findall(FENCE_RE.sub("", md.read_text())):
      if target.startswith(EXTERNAL):
        continue
      path_part, _, fragment = target.partition("#")
      dest = md if not path_part else (md.parent / path_part).resolve()
      if not dest.exists():
        errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
        continue
      if fragment and dest.suffix == ".md" \
          and fragment not in anchors_of(dest):
        errors.append(f"{md.relative_to(REPO)}: missing anchor -> {target}")
  for e in errors:
    print(f"ERROR: {e}", file=sys.stderr)
  n_links = sum(len(LINK_RE.findall(FENCE_RE.sub("", m.read_text())))
                for m in md_files if m.exists())
  print(f"checked {len(md_files)} markdown files, {n_links} links: "
        f"{len(errors)} broken")
  return 1 if errors else 0


if __name__ == "__main__":
  sys.exit(check())
